//! Run configuration: every experiment in the paper is a point in this
//! space. Parsed from CLI options (and JSON for fleet specs).

use crate::data::Env;
use crate::lrt::Variant;
use crate::nn::arch::DEFAULT_BATCH;
use crate::nvm::drift::DriftCfg;
use crate::nvm::fault::FaultCfg;
use crate::util::cli::Args;
use crate::util::json::Json;

/// The five training schemes of Fig. 6 (LRT twice: no-norm / max-norm).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    Inference,
    BiasOnly,
    Sgd,
    Lrt { variant: Variant },
}

impl Scheme {
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "inference" => Some(Scheme::Inference),
            "bias" | "bias-only" => Some(Scheme::BiasOnly),
            "sgd" => Some(Scheme::Sgd),
            "lrt" | "lrt-biased" => {
                Some(Scheme::Lrt { variant: Variant::Biased })
            }
            "lrt-unbiased" => {
                Some(Scheme::Lrt { variant: Variant::Unbiased })
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Inference => "inference",
            Scheme::BiasOnly => "bias-only",
            Scheme::Sgd => "sgd",
            Scheme::Lrt { variant: Variant::Biased } => "lrt-biased",
            Scheme::Lrt { variant: Variant::Unbiased } => "lrt-unbiased",
        }
    }

    pub fn trains_weights(&self) -> bool {
        matches!(self, Scheme::Sgd | Scheme::Lrt { .. })
    }

    pub fn trains_bias(&self) -> bool {
        !matches!(self, Scheme::Inference)
    }
}

/// Result of one declarative `key=value` application ([`RunConfig::set`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetOutcome {
    /// Key recognized, value parsed, field updated.
    Applied,
    /// Key recognized but the value failed to parse (nothing changed).
    BadValue,
    /// Not a `RunConfig` field (a scenario-specific axis).
    UnknownKey,
}

/// Full configuration of one online-adaptation run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub scheme: Scheme,
    pub env: Env,
    pub seed: u64,
    /// Online samples to stream.
    pub samples: usize,
    /// Offline pretraining samples before deployment.
    pub offline_samples: usize,
    pub lr_w: f32,
    pub lr_b: f32,
    pub rank: usize,
    pub use_maxnorm: bool,
    pub bn_stream: bool,
    /// Streaming-BN EMA horizon (eta = 1 - 1/bn_batch).
    pub bn_batch: f32,
    pub kappa_th: f32,
    /// Per-layer LRT flush batch sizes.
    pub batch: [usize; 6],
    /// Minimum update density to commit a flush (Appendix C).
    pub rho_min: f64,
    pub w_bits: u32,
    pub drift: DriftCfg,
    /// Record (step, acc, writes) every `log_every` samples.
    pub log_every: usize,
    /// Samples per distribution-shift segment (paper: 10_000; CI-sized
    /// runs shrink it so shifts actually occur within the run).
    pub shift_period: u64,
    /// Per-layer LRT variant override (Table 2 mixes biased convs with
    /// unbiased fcs etc.); defaults to the scheme's variant everywhere.
    pub lrt_variants: Option<[Variant; 6]>,
    /// Disable per-sample bias training (Table 3 "no bias training").
    pub train_bias: bool,
    /// NVM cell fault model (strictly opt-in; `FaultCfg::NONE` keeps
    /// every existing path byte-identical).
    pub fault: FaultCfg,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            scheme: Scheme::Lrt { variant: Variant::Biased },
            env: Env::Control,
            seed: 0,
            samples: 10_000,
            offline_samples: 4_000,
            lr_w: 0.01,
            lr_b: 0.01,
            rank: 4,
            use_maxnorm: true,
            bn_stream: true,
            bn_batch: 100.0,
            kappa_th: 100.0,
            batch: DEFAULT_BATCH,
            rho_min: 0.01,
            w_bits: 8,
            drift: DriftCfg::NONE,
            log_every: 250,
            shift_period: 10_000,
            lrt_variants: None,
            train_bias: true,
            fault: FaultCfg::NONE,
        }
    }
}

impl RunConfig {
    /// Build from CLI args (`adapt` subcommand options).
    pub fn from_args(args: &Args) -> RunConfig {
        let mut cfg = RunConfig::default();
        if let Some(s) = Scheme::parse(&args.str_opt("scheme", "lrt")) {
            cfg.scheme = s;
        }
        if let Some(e) = Env::parse(&args.str_opt("env", "control")) {
            cfg.env = e;
        }
        cfg.seed = args.u64_opt("seed", cfg.seed);
        cfg.samples = args.usize_opt("samples", cfg.samples);
        cfg.offline_samples =
            args.usize_opt("offline", cfg.offline_samples);
        cfg.lr_w = args.f64_opt("lr", cfg.lr_w as f64) as f32;
        cfg.lr_b = args.f64_opt("lr-bias", cfg.lr_w as f64) as f32;
        cfg.rank = args.usize_opt("rank", cfg.rank);
        cfg.use_maxnorm = !args.flag("no-norm");
        cfg.bn_stream = !args.flag("no-stream-bn");
        cfg.kappa_th = args.f64_opt("kappa", cfg.kappa_th as f64) as f32;
        cfg.rho_min = args.f64_opt("rho-min", cfg.rho_min);
        cfg.w_bits = args.usize_opt("w-bits", cfg.w_bits as usize) as u32;
        cfg.log_every = args.usize_opt("log-every", cfg.log_every);
        cfg.drift = match cfg.env {
            Env::AnalogDrift => {
                crate::nvm::drift::DriftCfg::analog(
                    args.f64_opt("sigma0", 10.0),
                )
            }
            Env::DigitalDrift => {
                crate::nvm::drift::DriftCfg::digital(args.f64_opt("p0", 10.0))
            }
            _ => DriftCfg::NONE,
        };
        cfg.fault.defect_p = args.f64_opt("fault-defect", cfg.fault.defect_p);
        cfg.fault.write_fail_p =
            args.f64_opt("fault-write-fail", cfg.fault.write_fail_p);
        cfg.fault.max_retries =
            args.usize_opt("fault-retries", cfg.fault.max_retries as usize)
                as u32;
        cfg.fault.var_sigma = args.f64_opt("fault-var", cfg.fault.var_sigma);
        cfg.fault.wearout = args.flag("fault-wearout");
        cfg.fault.wearout_spread = args
            .f64_opt("fault-wearout-spread", cfg.fault.wearout_spread);
        cfg.fault.endurance =
            args.f64_opt("fault-endurance", cfg.fault.endurance);
        cfg.fault.seed = args.u64_opt("fault-seed", cfg.fault.seed);
        cfg
    }

    /// Apply one declarative `key=value` assignment — the bridge between
    /// a sweep-grid axis (or config file entry) and this struct. Keys are
    /// canonical snake_case RunConfig field names (hyphens accepted);
    /// `env` also installs that environment's default drift process
    /// (paper magnitudes), and `drift_sigma` / `drift_p` override it.
    /// The tri-state return lets the sweep grid distinguish a
    /// scenario-specific axis (`UnknownKey`, skipped) from a config axis
    /// with a malformed value (`BadValue`, an error to surface — never
    /// something to silently ignore).
    pub fn set(&mut self, key: &str, value: &str) -> SetOutcome {
        use SetOutcome::{Applied, BadValue, UnknownKey};
        fn p<T: std::str::FromStr>(v: &str) -> Option<T> {
            v.parse().ok()
        }
        fn pb(v: &str) -> Option<bool> {
            match v {
                "true" | "1" | "yes" | "on" => Some(true),
                "false" | "0" | "no" | "off" => Some(false),
                _ => None,
            }
        }
        let ok = |applied: bool| if applied { Applied } else { BadValue };
        let key = key.replace('-', "_");
        match key.as_str() {
            "scheme" => ok(match Scheme::parse(value) {
                Some(s) => {
                    self.scheme = s;
                    true
                }
                None => false,
            }),
            "env" => ok(match Env::parse(value) {
                Some(e) => {
                    self.env = e;
                    self.drift = match e {
                        Env::AnalogDrift => DriftCfg::analog(10.0),
                        Env::DigitalDrift => DriftCfg::digital(10.0),
                        _ => DriftCfg::NONE,
                    };
                    true
                }
                None => false,
            }),
            "seed" => ok(p(value).map(|v| self.seed = v).is_some()),
            "samples" => ok(p(value).map(|v| self.samples = v).is_some()),
            "offline" | "offline_samples" => {
                ok(p(value).map(|v| self.offline_samples = v).is_some())
            }
            "lr" => ok(match p::<f32>(value) {
                Some(v) => {
                    self.lr_w = v;
                    self.lr_b = v;
                    true
                }
                None => false,
            }),
            "lr_w" => ok(p(value).map(|v| self.lr_w = v).is_some()),
            "lr_b" => ok(p(value).map(|v| self.lr_b = v).is_some()),
            "rank" => ok(p(value).map(|v| self.rank = v).is_some()),
            "maxnorm" | "use_maxnorm" => {
                ok(pb(value).map(|v| self.use_maxnorm = v).is_some())
            }
            "bn_stream" => {
                ok(pb(value).map(|v| self.bn_stream = v).is_some())
            }
            "bn_batch" => ok(p(value).map(|v| self.bn_batch = v).is_some()),
            "kappa" | "kappa_th" => {
                ok(p(value).map(|v| self.kappa_th = v).is_some())
            }
            "rho_min" => ok(p(value).map(|v| self.rho_min = v).is_some()),
            "bits" | "w_bits" => {
                ok(p(value).map(|v| self.w_bits = v).is_some())
            }
            "log_every" => {
                ok(p(value).map(|v| self.log_every = v).is_some())
            }
            "shift_period" => {
                ok(p(value).map(|v| self.shift_period = v).is_some())
            }
            "train_bias" => {
                ok(pb(value).map(|v| self.train_bias = v).is_some())
            }
            "drift_sigma" => ok(match p(value) {
                Some(v) => {
                    self.drift = DriftCfg::analog(v);
                    true
                }
                None => false,
            }),
            "drift_p" => ok(match p(value) {
                Some(v) => {
                    self.drift = DriftCfg::digital(v);
                    true
                }
                None => false,
            }),
            // fault-model knobs mutate individual FaultCfg fields so
            // grid axes compose (defect x write-fail sweeps etc.)
            "fault_defect" => {
                ok(p(value).map(|v| self.fault.defect_p = v).is_some())
            }
            "fault_write_fail" => {
                ok(p(value).map(|v| self.fault.write_fail_p = v).is_some())
            }
            "fault_retries" => {
                ok(p(value).map(|v| self.fault.max_retries = v).is_some())
            }
            "fault_var" => {
                ok(p(value).map(|v| self.fault.var_sigma = v).is_some())
            }
            "fault_wearout" => {
                ok(pb(value).map(|v| self.fault.wearout = v).is_some())
            }
            "fault_wearout_spread" => ok(p(value)
                .map(|v| self.fault.wearout_spread = v)
                .is_some()),
            "fault_endurance" => {
                ok(p(value).map(|v| self.fault.endurance = v).is_some())
            }
            "fault_seed" => {
                ok(p(value).map(|v| self.fault.seed = v).is_some())
            }
            _ => UnknownKey,
        }
    }

    /// Variant when running LRT (Biased otherwise, unused).
    pub fn variant(&self) -> Variant {
        match self.scheme {
            Scheme::Lrt { variant } => variant,
            _ => Variant::Biased,
        }
    }

    pub fn bn_eta(&self) -> f32 {
        1.0 - 1.0 / self.bn_batch
    }

    /// JSON summary written into reports.
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("scheme".into(), Json::Str(self.scheme.name().into()));
        m.insert("env".into(), Json::Str(self.env.name().into()));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("samples".into(), Json::Num(self.samples as f64));
        m.insert("lr_w".into(), Json::Num(self.lr_w as f64));
        m.insert("rank".into(), Json::Num(self.rank as f64));
        m.insert("maxnorm".into(), Json::Bool(self.use_maxnorm));
        m.insert("w_bits".into(), Json::Num(self.w_bits as f64));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parsing() {
        assert_eq!(Scheme::parse("sgd"), Some(Scheme::Sgd));
        assert_eq!(
            Scheme::parse("lrt-unbiased"),
            Some(Scheme::Lrt { variant: Variant::Unbiased })
        );
        assert_eq!(Scheme::parse("nope"), None);
        assert!(!Scheme::Inference.trains_bias());
        assert!(Scheme::BiasOnly.trains_bias());
        assert!(!Scheme::BiasOnly.trains_weights());
    }

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            [
                "adapt", "--scheme", "sgd", "--env", "analog", "--lr",
                "0.03", "--samples", "500", "--no-norm",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert_eq!(cfg.scheme, Scheme::Sgd);
        assert_eq!(cfg.env, Env::AnalogDrift);
        assert!(cfg.drift.enabled());
        assert!((cfg.lr_w - 0.03).abs() < 1e-9);
        assert_eq!(cfg.samples, 500);
        assert!(!cfg.use_maxnorm);
    }

    #[test]
    fn bn_eta_formula() {
        let cfg = RunConfig::default();
        assert!((cfg.bn_eta() - 0.99).abs() < 1e-6);
    }

    #[test]
    fn set_maps_grid_axes_onto_fields() {
        use SetOutcome::{Applied, BadValue, UnknownKey};
        let mut cfg = RunConfig::default();
        for (k, v) in [
            ("rank", "8"),
            ("bits", "4"),
            ("lr", "0.03"),
            ("kappa-th", "1e8"),
            ("maxnorm", "false"),
            ("env", "analog"),
        ] {
            assert_eq!(cfg.set(k, v), Applied, "{k}={v}");
        }
        assert_eq!(cfg.rank, 8);
        assert_eq!(cfg.w_bits, 4);
        assert!((cfg.lr_w - 0.03).abs() < 1e-9 && (cfg.lr_b - 0.03).abs() < 1e-9);
        assert!((cfg.kappa_th - 1e8).abs() < 1.0);
        assert!(!cfg.use_maxnorm);
        assert_eq!(cfg.env, Env::AnalogDrift);
        assert!(cfg.drift.enabled());
        assert_eq!(cfg.set("drift_sigma", "30"), Applied);
        assert!((cfg.drift.sigma0 - 30.0).abs() < 1e-12);
        // unknown keys vs bad values are distinguished, never conflated
        assert_eq!(cfg.set("no_such_field", "1"), UnknownKey);
        assert_eq!(cfg.set("rank", "banana"), BadValue);
        assert_eq!(cfg.rank, 8, "failed set must not change the field");
    }

    #[test]
    fn fault_keys_compose_and_default_to_none() {
        use SetOutcome::{Applied, BadValue};
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.fault, FaultCfg::NONE);
        assert!(!cfg.fault.enabled());
        for (k, v) in [
            ("fault_defect", "0.01"),
            ("fault-write-fail", "0.02"),
            ("fault_retries", "5"),
            ("fault_var", "0.1"),
            ("fault_wearout", "true"),
            ("fault_wearout_spread", "0.5"),
            ("fault_endurance", "1000"),
            ("fault_seed", "7"),
        ] {
            assert_eq!(cfg.set(k, v), Applied, "{k}={v}");
        }
        assert!((cfg.fault.defect_p - 0.01).abs() < 1e-12);
        assert!((cfg.fault.write_fail_p - 0.02).abs() < 1e-12);
        assert_eq!(cfg.fault.max_retries, 5);
        assert!((cfg.fault.var_sigma - 0.1).abs() < 1e-12);
        assert!(cfg.fault.wearout);
        assert!((cfg.fault.endurance - 1000.0).abs() < 1e-12);
        assert_eq!(cfg.fault.seed, 7);
        assert!(cfg.fault.enabled());
        assert_eq!(cfg.set("fault_defect", "banana"), BadValue);
        assert!((cfg.fault.defect_p - 0.01).abs() < 1e-12);
    }

    #[test]
    fn fault_flags_from_args() {
        let args = Args::parse(
            [
                "adapt",
                "--fault-defect",
                "0.05",
                "--fault-write-fail",
                "0.01",
                "--fault-wearout",
                "--fault-seed",
                "3",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let cfg = RunConfig::from_args(&args);
        assert!((cfg.fault.defect_p - 0.05).abs() < 1e-12);
        assert!((cfg.fault.write_fail_p - 0.01).abs() < 1e-12);
        assert!(cfg.fault.wearout);
        assert_eq!(cfg.fault.seed, 3);
        assert!(cfg.fault.enabled());
        // no flags -> NONE
        let none = RunConfig::from_args(&Args::parse(
            ["adapt"].iter().map(|s| s.to_string()),
        ));
        assert_eq!(none.fault, FaultCfg::NONE);
    }
}
