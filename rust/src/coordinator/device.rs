//! A simulated NVM edge device running the native engine: weight arrays
//! in simulated RRAM, auxiliary training state in (simulated) SRAM, and
//! the per-sample step for every training scheme of Section 7.1.

use super::config::{RunConfig, Scheme};
use super::scheduler::{FlushDecision, FlushScheduler};
use crate::lrt::LrtState;
use crate::nn::arch::{LAYER_DIMS, N_LAYERS};
use crate::nn::model::{
    self, apply_bias_updates, argmax, AuxState, Params,
};
use crate::nn::workspace::{self, Workspace};
use crate::nvm::{drift, fault, NvmArray};
use crate::quant::qw_bits;
use crate::tensor::kernels;
use crate::util::rng::Rng;

pub struct NativeDevice {
    pub cfg: RunConfig,
    pub params: Params,
    pub arrays: Vec<NvmArray>,
    pub aux: AuxState,
    pub lrt: Vec<LrtState>,
    pub sched: Vec<FlushScheduler>,
    pub kappa_skips: u64,
    /// Weights in `params` are stale vs the NVM arrays (after a commit
    /// or drift round); cleared by `read_weights`.
    weights_dirty: bool,
    /// Monotone count of NVM weight-change events (commits that wrote
    /// cells, drift rounds, external hydrations). Never reset: the
    /// serving path's snapshot publisher compares it across steps to
    /// detect that a flush landed (`serve::snapshot`).
    weights_version: u64,
    rng: Rng,
    drift_rng: Rng,
    /// Retained scratch for the whole training step — after the first
    /// step a steady-state `step` performs zero heap allocations
    /// (`tests/alloc_steady_state.rs`).
    pub ws: Workspace,
}

impl NativeDevice {
    /// Deploy: program the NVM arrays from (offline-trained) parameters.
    pub fn new(
        cfg: RunConfig,
        params: Params,
        aux: AuxState,
    ) -> NativeDevice {
        let qw = qw_bits(cfg.w_bits);
        let mut arrays: Vec<NvmArray> = params
            .w
            .iter()
            .map(|w| NvmArray::program(w, qw))
            .collect();
        if cfg.fault.enabled() {
            // i.i.d. per-device defect maps: the device's fault seed is
            // FNV-mixed from (fault seed, device/run seed), then split
            // per layer — see nvm::fault
            let dev_seed =
                fault::device_fault_seed(cfg.fault.seed, cfg.seed);
            for (layer, arr) in arrays.iter_mut().enumerate() {
                arr.install_fault(
                    &cfg.fault,
                    fault::array_fault_seed(dev_seed, layer),
                );
            }
        }
        let lrt = LAYER_DIMS
            .iter()
            .map(|&(n_o, n_i)| LrtState::new(n_o, n_i, cfg.rank))
            .collect();
        // Per-layer affinity hints: the flush evaluation of layer i
        // costs ~n_o*n_i*(rank+2) multiply-adds (factor reconstruction
        // + density scan), so tiny conv layers stay sequential and the
        // big fc layers take only the workers that cost justifies.
        let sched = cfg
            .batch
            .iter()
            .zip(LAYER_DIMS.iter())
            .map(|(&b, &(n_o, n_i))| {
                FlushScheduler::new(b, cfg.rho_min).with_par_cap(
                    kernels::suggested_workers(n_o * n_i * (cfg.rank + 2)),
                )
            })
            .collect();
        let mut rng = Rng::new(cfg.seed ^ 0xDE71CE);
        let drift_rng = rng.fork(0xD217F7);
        NativeDevice {
            cfg,
            params,
            arrays,
            aux,
            lrt,
            sched,
            kappa_skips: 0,
            weights_dirty: true,
            weights_version: 0,
            rng,
            drift_rng,
            ws: Workspace::new(),
        }
    }

    /// Record that the NVM arrays changed behind `params`: stale until
    /// the next `read_weights`, and one tick on the version counter.
    fn note_weight_change(&mut self) {
        self.weights_dirty = true;
        self.weights_version += 1;
    }

    /// Monotone weight-change counter: advances every time a commit
    /// writes cells, a drift round runs, or a hydration path marks the
    /// arrays dirty. `read_weights` does not touch it — it counts NVM
    /// changes, not syncs.
    pub fn weights_version(&self) -> u64 {
        self.weights_version
    }

    /// Refresh the logical weights from NVM (drift may have moved them).
    /// No-op when nothing was committed or drifted since the last sync.
    pub fn read_weights(&mut self) {
        if !self.weights_dirty {
            return;
        }
        for (i, arr) in self.arrays.iter().enumerate() {
            arr.read_into(&mut self.params.w[i]);
        }
        self.weights_dirty = false;
    }

    /// Supervised online step: predict, learn from the revealed label.
    ///
    /// Runs entirely on the device's retained [`Workspace`]: after the
    /// first (warm-up) step, a steady-state step performs zero heap
    /// allocations on this thread.
    pub fn step(&mut self, image: &[f32], label: usize) -> (f32, bool) {
        self.read_weights();
        let cfg = &self.cfg;
        let train = cfg.scheme != Scheme::Inference;
        model::forward_into(
            &self.params,
            &mut self.aux,
            image,
            cfg.bn_eta(),
            cfg.bn_stream,
            cfg.w_bits,
            train,
            &mut self.ws,
        );
        let pred = argmax(&self.ws.caches.logits);
        let loss = model::softmax_xent_into(
            &self.ws.caches.logits,
            label,
            &mut self.ws.dlogits,
        );
        let correct = pred == label;
        if !train {
            return (loss, correct);
        }

        let use_mn = cfg.use_maxnorm;
        model::backward_into(
            &self.params,
            &mut self.aux,
            &mut self.ws,
            use_mn,
            cfg.w_bits,
        );
        apply_bias_updates(
            &mut self.params,
            &self.ws.grads,
            cfg.lr_b,
            cfg.scheme.trains_bias() && cfg.train_bias,
        );

        match cfg.scheme {
            Scheme::Sgd => self.sgd_weight_step(),
            Scheme::Lrt { variant } => self.lrt_weight_step(variant),
            _ => {}
        }
        (loss, correct)
    }

    fn sgd_weight_step(&mut self) {
        let qw = qw_bits(self.cfg.w_bits);
        let lr_w = self.cfg.lr_w;
        let Workspace { grads, delta, cand, .. } = &mut self.ws;
        for i in 0..N_LAYERS {
            grads.full_into(i, &mut delta[i]);
            cand[i].copy_from(&self.params.w[i]);
            for (wv, &g) in cand[i].data.iter_mut().zip(delta[i].data.iter())
            {
                *wv = qw.q(*wv - lr_w * g);
            }
            if self.arrays[i].commit(&cand[i]) > 0 {
                // note_weight_change inlined: the ws borrow is live
                self.weights_dirty = true;
                self.weights_version += 1;
            }
        }
    }

    fn lrt_weight_step(&mut self, variant: crate::lrt::Variant) {
        let qw = qw_bits(self.cfg.w_bits);
        for i in 0..N_LAYERS {
            // conv layers: one Kronecker update per output pixel
            // (Appendix B.2); fc layers: one per sample. The backward
            // pass hands us Mat-of-rows factor blocks, so the whole
            // block goes to the batched rank update in one call.
            let layer_variant = self
                .cfg
                .lrt_variants
                .map(|v| v[i])
                .unwrap_or(variant);
            self.kappa_skips += self.lrt[i].update_batch(
                &self.ws.grads.dzw[i],
                &self.ws.grads.ain[i],
                &mut self.rng,
                layer_variant,
                self.cfg.kappa_th,
            );
            if let FlushDecision::Evaluate { lr_scale } =
                self.sched[i].on_sample()
            {
                // Per-layer affinity: cap this evaluation's kernel
                // parallelism to what the layer's size warrants.
                let _aff = kernels::affinity(self.sched[i].par_cap);
                self.lrt[i].delta_into(&mut self.ws.delta[i]);
                let lr_eff = self.cfg.lr_w * lr_scale;
                let Workspace { delta, cand, .. } = &mut self.ws;
                cand[i].copy_from(&self.params.w[i]);
                for (wv, &g) in
                    cand[i].data.iter_mut().zip(delta[i].data.iter())
                {
                    *wv = qw.q(*wv - lr_eff * g);
                }
                let density = self.arrays[i].density_of(&cand[i]);
                if self.sched[i].decide(density) {
                    if self.arrays[i].commit(&cand[i]) > 0 {
                        // note_weight_change inlined: ws borrow is live
                        self.weights_dirty = true;
                        self.weights_version += 1;
                    }
                    self.lrt[i].reset();
                }
            }
        }
    }

    /// Batched online step over a chunk of samples.
    ///
    /// Training schemes are inherently sequential per sample (streaming
    /// BN, per-sample bias updates, MGS rank updates), so the chunk is
    /// processed in order and results are numerically identical to
    /// per-sample `step` calls (`tests/kernel_parity.rs` pins this).
    /// Pure inference has no cross-sample state, so those chunks fan out
    /// across the shared worker pool.
    pub fn step_batch(
        &mut self,
        images: &[Vec<f32>],
        labels: &[usize],
    ) -> Vec<(f32, bool)> {
        assert_eq!(images.len(), labels.len());
        if self.cfg.scheme == Scheme::Inference {
            self.read_weights();
            let params = &self.params;
            let aux = &self.aux;
            let cfg = &self.cfg;
            // Each pool worker scores a contiguous slice with one
            // retained forward-only workspace and one AuxState clone
            // (eval-mode forward leaves AuxState untouched; the clone
            // only satisfies the &mut signature). Forwards are
            // independent, so the chunking changes nothing numerically
            // — it just keeps per-sample traffic allocation-free, and
            // the parked pool keeps per-batch dispatch spawn-free.
            return workspace::map_samples(
                images.len(),
                || aux.clone(),
                |s, ws, aux_w| {
                    model::forward_into(
                        params,
                        aux_w,
                        &images[s],
                        cfg.bn_eta(),
                        cfg.bn_stream,
                        cfg.w_bits,
                        false,
                        ws,
                    );
                    let loss = model::softmax_xent_into(
                        &ws.caches.logits,
                        labels[s],
                        &mut ws.dlogits,
                    );
                    (loss, argmax(&ws.caches.logits) == labels[s])
                },
            );
        }
        images
            .iter()
            .zip(labels.iter())
            .map(|(img, &label)| self.step(img, label))
            .collect()
    }

    /// Inject one round of the configured NVM drift.
    pub fn drift(&mut self) {
        if !self.cfg.drift.enabled() {
            return;
        }
        let cfg = self.cfg.drift;
        for arr in &mut self.arrays {
            drift::apply(arr, &mut self.drift_rng, &cfg);
            // stuck cells do not drift: re-pin their frozen levels
            // (no-op without a fault model)
            arr.reassert_stuck();
        }
        self.note_weight_change();
    }

    /// Re-derive and install the per-array fault maps under a device
    /// fault seed — the sharded fleet's hydration hook (a carcass is
    /// reused across records, so each hydration must re-key the maps
    /// to its record's device).
    pub(crate) fn install_fault_seed(&mut self, dev_fault_seed: u64) {
        let fcfg = self.cfg.fault;
        for (layer, arr) in self.arrays.iter_mut().enumerate() {
            arr.install_fault(
                &fcfg,
                fault::array_fault_seed(dev_fault_seed, layer),
            );
        }
    }

    /// Aggregate fault telemetry across the weight arrays; `None`
    /// when no fault model is configured (keeps NONE reports
    /// byte-identical).
    pub fn fault_summary(&self) -> Option<fault::FaultSummary> {
        if !self.cfg.fault.enabled() {
            return None;
        }
        let mut sum = fault::FaultSummary::default();
        for arr in &self.arrays {
            if let Some(fs) = arr.fault() {
                fault::merge(&mut sum, fs.summarize(arr.len()));
            }
        }
        Some(sum)
    }

    pub fn max_cell_writes(&self) -> u64 {
        self.arrays.iter().map(|a| a.max_cell_writes()).max().unwrap_or(0)
    }

    pub fn total_writes(&self) -> u64 {
        self.arrays.iter().map(|a| a.total_writes).sum()
    }

    pub fn flush_stats(&self) -> (u64, u64) {
        (
            self.sched.iter().map(|s| s.commits).sum(),
            self.sched.iter().map(|s| s.deferrals).sum(),
        )
    }

    /// Forward-only prediction (validation / accuracy probes).
    pub fn infer(&mut self, image: &[f32]) -> usize {
        self.read_weights();
        model::forward_into(
            &self.params,
            &mut self.aux,
            image,
            self.cfg.bn_eta(),
            self.cfg.bn_stream,
            self.cfg.w_bits,
            false,
            &mut self.ws,
        );
        argmax(&self.ws.caches.logits)
    }

    /// Auxiliary SRAM the LRT accumulators occupy at 16-bit (LAM check).
    pub fn lrt_aux_bytes(&self) -> usize {
        self.lrt.iter().map(|s| s.aux_bytes(16)).sum()
    }

    /// Clones of the device's RNG streams, in their current positions
    /// (sharded-fleet record suspension).
    pub(crate) fn streams(&self) -> (Rng, Rng) {
        (self.rng.clone(), self.drift_rng.clone())
    }

    /// Hydrate the RNG streams from a suspended record.
    pub(crate) fn set_streams(&mut self, rng: Rng, drift_rng: Rng) {
        self.rng = rng;
        self.drift_rng = drift_rng;
    }

    /// Force a weight re-read before the next step — used after a
    /// hydration path mutates `arrays` behind the device's back.
    pub(crate) fn mark_weights_dirty(&mut self) {
        self.note_weight_change();
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::RunConfig;

    fn mk(scheme: Scheme) -> NativeDevice {
        let mut cfg = RunConfig::default();
        cfg.scheme = scheme;
        cfg.batch = [2, 2, 2, 2, 4, 4]; // small for tests
        let mut rng = Rng::new(1);
        let params = Params::init(&mut rng, cfg.w_bits);
        NativeDevice::new(cfg, params, AuxState::new())
    }

    fn image(seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..784).map(|_| rng.normal_f32(0.5, 0.5).clamp(0.0, 2.0)).collect()
    }

    #[test]
    fn inference_never_writes() {
        let mut dev = mk(Scheme::Inference);
        for t in 0..5 {
            dev.step(&image(t), (t % 10) as usize);
        }
        assert_eq!(dev.total_writes(), 0);
    }

    #[test]
    fn bias_only_never_writes_weights() {
        let mut dev = mk(Scheme::BiasOnly);
        for t in 0..5 {
            dev.step(&image(t), (t % 10) as usize);
        }
        assert_eq!(dev.total_writes(), 0);
        // but biases moved
        assert!(dev.params.b.iter().any(|b| b.iter().any(|&v| v != 0.0)));
    }

    #[test]
    fn sgd_writes_every_sample_lrt_batches() {
        let mut sgd = mk(Scheme::Sgd);
        let mut lrt = mk(Scheme::Lrt { variant: crate::lrt::Variant::Biased });
        for t in 0..8 {
            sgd.step(&image(t), (t % 10) as usize);
            lrt.step(&image(t), (t % 10) as usize);
        }
        assert!(sgd.arrays.iter().map(|a| a.commits).sum::<u64>() >= 8);
        // LRT commits at most every batch samples per layer
        let lrt_commits: u64 = lrt.arrays.iter().map(|a| a.commits).sum();
        assert!(lrt_commits <= 4 * 6, "{lrt_commits}");
        assert!(lrt.lrt_aux_bytes() > 0);
    }

    /// The paper's core claim surface: batching the engine never
    /// reports more NVM writes than the equivalent per-sample steps —
    /// and because training chunks are sequential by construction, the
    /// counters are in fact identical.
    #[test]
    fn step_batch_writes_never_exceed_per_sample() {
        for scheme in
            [Scheme::Sgd, Scheme::Lrt { variant: crate::lrt::Variant::Biased }]
        {
            crate::util::prop::check("batch-write-bound", 3, |rng| {
                let n = 4 + rng.below(4);
                let images: Vec<Vec<f32>> =
                    (0..n).map(|_| {
                        (0..784)
                            .map(|_| {
                                rng.normal_f32(0.5, 0.5).clamp(0.0, 2.0)
                            })
                            .collect()
                    })
                    .collect();
                let labels: Vec<usize> =
                    (0..n).map(|_| rng.below(10)).collect();
                let mut per = mk(scheme);
                let mut bat = mk(scheme);
                for (img, &l) in images.iter().zip(labels.iter()) {
                    per.step(img, l);
                }
                bat.step_batch(&images, &labels);
                crate::prop_assert!(
                    bat.max_cell_writes() <= per.max_cell_writes(),
                    "batched worst cell exceeded per-sample"
                );
                // subsumes "never more writes": training chunks are
                // sequential by construction, so the counters match
                crate::prop_assert!(
                    bat.total_writes() == per.total_writes(),
                    "batched writes {} != per-sample {}",
                    bat.total_writes(),
                    per.total_writes()
                );
                Ok(())
            });
        }
    }

    #[test]
    fn weights_version_counts_nvm_changes_not_syncs() {
        let mut dev = mk(Scheme::Sgd);
        assert_eq!(dev.weights_version(), 0);
        dev.read_weights();
        assert_eq!(dev.weights_version(), 0, "sync must not tick");
        dev.step(&image(1), 3);
        let after_commit = dev.weights_version();
        assert!(after_commit > 0, "SGD commit must tick the version");
        dev.read_weights();
        assert_eq!(dev.weights_version(), after_commit);
        dev.cfg.drift = crate::nvm::drift::DriftCfg::analog(100.0);
        dev.drift();
        assert_eq!(dev.weights_version(), after_commit + 1);
        // inference never changes weights, so the version holds
        let mut inf = mk(Scheme::Inference);
        for t in 0..3 {
            inf.step(&image(t), 0);
        }
        assert_eq!(inf.weights_version(), 0);
    }

    #[test]
    fn drift_moves_weights() {
        let mut dev = mk(Scheme::Inference);
        dev.cfg.drift = crate::nvm::drift::DriftCfg::analog(100.0);
        let before = dev.arrays[4].read();
        for _ in 0..50 {
            dev.drift();
        }
        let after = dev.arrays[4].read();
        assert_ne!(before.data, after.data);
    }

    fn mk_faulty(scheme: Scheme, seed: u64) -> NativeDevice {
        let mut cfg = RunConfig::default();
        cfg.scheme = scheme;
        cfg.seed = seed;
        cfg.batch = [2, 2, 2, 2, 4, 4];
        cfg.fault.defect_p = 0.02;
        cfg.fault.write_fail_p = 0.05;
        let mut rng = Rng::new(1);
        let params = Params::init(&mut rng, cfg.w_bits);
        NativeDevice::new(cfg, params, AuxState::new())
    }

    #[test]
    fn fault_maps_are_per_device_iid_and_deterministic() {
        let a = mk_faulty(Scheme::Sgd, 100);
        let b = mk_faulty(Scheme::Sgd, 100);
        let c = mk_faulty(Scheme::Sgd, 101);
        for i in 0..a.arrays.len() {
            assert_eq!(
                a.arrays[i].fault().unwrap().stuck_flags(),
                b.arrays[i].fault().unwrap().stuck_flags(),
                "same device seed must give the same map (layer {i})"
            );
        }
        // a different device draws a different map somewhere
        assert!(
            (0..a.arrays.len()).any(|i| {
                a.arrays[i].fault().unwrap().stuck_flags()
                    != c.arrays[i].fault().unwrap().stuck_flags()
            }),
            "device seeds 100 and 101 drew identical defect maps"
        );
        let sum = a.fault_summary().unwrap();
        assert!(sum.factory_stuck > 0, "2% of ~90k cells must stick");
        assert!(sum.cells > 0);
        // no fault configured -> no summary, no model installed
        let plain = mk(Scheme::Sgd);
        assert!(plain.fault_summary().is_none());
        assert!(plain.arrays.iter().all(|a| a.fault().is_none()));
    }

    #[test]
    fn training_degrades_gracefully_through_defects() {
        // training keeps running (and writing) with defects present
        let mut dev = mk_faulty(Scheme::Sgd, 7);
        for t in 0..6 {
            dev.step(&image(t), (t % 10) as usize);
        }
        assert!(dev.total_writes() > 0);
        let sum = dev.fault_summary().unwrap();
        assert_eq!(
            sum.pulses_attempted,
            sum.pulse_successes + sum.retry_pulses + sum.retired,
            "device-level retry accounting must close: {sum:?}"
        );
        assert_eq!(dev.total_writes(), sum.pulses_attempted);
    }

    #[test]
    fn drift_does_not_move_stuck_cells() {
        let mut dev = mk_faulty(Scheme::Inference, 5);
        dev.cfg.drift = crate::nvm::drift::DriftCfg::analog(100.0);
        let arr = &dev.arrays[4];
        let stuck: Vec<usize> = (0..arr.len())
            .filter(|&i| arr.fault().unwrap().is_stuck(i))
            .collect();
        assert!(!stuck.is_empty());
        let before: Vec<f32> =
            stuck.iter().map(|&i| dev.arrays[4].raw()[i]).collect();
        for _ in 0..20 {
            dev.drift();
        }
        let after: Vec<f32> =
            stuck.iter().map(|&i| dev.arrays[4].raw()[i]).collect();
        assert_eq!(before, after, "drift moved stuck cells");
    }
}
