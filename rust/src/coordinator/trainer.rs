//! Single-device online adaptation loop (the Fig. 6 experiment driver):
//! offline pretraining -> deployment -> supervised online stream with
//! drift injection and metrics.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use super::config::{RunConfig, Scheme};
use super::device::NativeDevice;
use super::metrics::{DeviceTelemetry, Metrics, RunReport};
use crate::data::online::{OnlineStream, Partition};
use crate::nn::model::{self, Params};
use crate::nn::workspace::{self, Workspace};
use crate::util::hash::fnv1a64_words;
use crate::util::rng::Rng;

/// Domain tag for hashed write-event identifiers fed to the power-sum
/// sketch (keeps them disjoint from the other fnv-derived id spaces).
const WRITE_EVENT_TAG: u64 = 0x57E1_7E5u64;

/// Offline pretraining: quantized SGD with max-norm on the offline
/// partition (the paper's cloud-side phase before deployment).
///
/// Runs on one retained [`Workspace`], so the per-sample loop is
/// allocation-free apart from the stream's sample synthesis.
pub fn pretrain(cfg: &RunConfig, verbose: bool) -> (Params, model::AuxState) {
    let mut rng = Rng::new(cfg.seed ^ 0x0FF11E);
    let mut params = Params::init(&mut rng, cfg.w_bits);
    let mut aux = model::AuxState::new();
    let stream =
        OnlineStream::new(cfg.seed ^ 0x0FF, Partition::Offline, crate::data::Env::Control);
    let qw = crate::quant::qw_bits(cfg.w_bits);
    let lr_w = 0.02f32;
    let lr_b = 0.02f32;
    let mut correct_recent = 0usize;
    let mut ws = Workspace::new();
    for t in 0..cfg.offline_samples {
        let s = stream.sample(t as u64);
        model::forward_into(
            &params, &mut aux, &s.image, cfg.bn_eta(), true, cfg.w_bits,
            true, &mut ws,
        );
        let pred = model::argmax(&ws.caches.logits);
        if pred == s.label {
            correct_recent += 1;
        }
        model::softmax_xent_into(&ws.caches.logits, s.label, &mut ws.dlogits);
        model::backward_into(&params, &mut aux, &mut ws, true, cfg.w_bits);
        {
            let Workspace { grads, delta, .. } = &mut ws;
            for i in 0..crate::nn::arch::N_LAYERS {
                grads.full_into(i, &mut delta[i]);
                for (wv, &g) in
                    params.w[i].data.iter_mut().zip(delta[i].data.iter())
                {
                    *wv = qw.q(*wv - lr_w * g);
                }
            }
        }
        model::apply_bias_updates(&mut params, &ws.grads, lr_b, true);
        if verbose && (t + 1) % 1000 == 0 {
            eprintln!(
                "  pretrain {t}: acc(last 1k) = {:.3}",
                correct_recent as f64 / 1000.0
            );
            correct_recent = 0;
        }
    }
    (params, aux)
}

/// Everything `pretrain` actually reads from the config: sweeps whose
/// cells agree on this key deploy one shared offline phase.
type PretrainKey = (u64, usize, u32, u32);

fn pretrain_key(cfg: &RunConfig) -> PretrainKey {
    (cfg.seed, cfg.offline_samples, cfg.w_bits, cfg.bn_batch.to_bits())
}

static PRETRAIN_CACHE: OnceLock<
    Mutex<HashMap<PretrainKey, (Params, model::AuxState)>>,
> = OnceLock::new();

/// Memoized `pretrain`: grid cells that share (seed, offline budget,
/// bitwidth, BN horizon) reuse one offline phase instead of re-running
/// it per cell — the registry's replacement for the hand-rolled shared
/// pretraining the old fig6 driver did. `pretrain` is a pure function
/// of the key, so the cache can only change wall-clock, never numbers.
/// The lock IS held while computing a cold key: sweep cells racing on
/// the same pretraining block until the first one fills it (the
/// computing thread never needs the blocked ones — the kernels degrade
/// to sequential when the pool budget is taken — so this cannot
/// deadlock, and it beats every racer redundantly pretraining).
pub fn pretrain_cached(cfg: &RunConfig) -> (Params, model::AuxState) {
    let cache = PRETRAIN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let key = pretrain_key(cfg);
    let mut guard = cache.lock().unwrap();
    if let Some(hit) = guard.get(&key) {
        return hit.clone();
    }
    let out = pretrain(cfg, false);
    guard.insert(key, out.clone());
    out
}

pub struct Trainer {
    pub cfg: RunConfig,
    pub device: NativeDevice,
    pub stream: OnlineStream,
    pub metrics: Metrics,
}

impl Trainer {
    /// Pretrain + deploy. Pass cached `params` to share the offline phase
    /// across the schemes of one figure (they deploy the same model).
    pub fn new(
        cfg: RunConfig,
        params: Params,
        aux: model::AuxState,
    ) -> Trainer {
        let mut stream =
            OnlineStream::new(cfg.seed, Partition::Online, cfg.env);
        stream.shift_period = cfg.shift_period;
        let metrics = Metrics::new(500);
        let device = NativeDevice::new(cfg.clone(), params, aux);
        Trainer { cfg, device, stream, metrics }
    }

    pub fn with_pretraining(cfg: RunConfig) -> Trainer {
        let (params, aux) = pretrain(&cfg, false);
        Trainer::new(cfg, params, aux)
    }

    /// Stream `cfg.samples` online samples; returns the run report.
    ///
    /// Samples go to the device in chunks (`NativeDevice::step_batch`)
    /// whose boundaries land exactly on the per-sample loop's drift /
    /// logging cadence, so reports are numerically identical to
    /// per-sample stepping while inference-heavy chunks fan out across
    /// the shared worker pool. (Training chunks are processed strictly
    /// in order inside `step_batch`, so flush boundaries *within* a
    /// chunk behave exactly as per-sample stepping.)
    pub fn run(&mut self) -> RunReport {
        const MAX_CHUNK: usize = 64;
        let t0 = std::time::Instant::now();
        // Clamp once and reuse for both the chunk caps and the firing
        // checks below, so a (mis)configured 0 means "every sample"
        // instead of a divide-by-zero at the modulo.
        let drift_every = self.cfg.drift.every.max(1) as usize;
        let log_every = self.cfg.log_every.max(1);
        let mut t = 0usize;
        // Chunk buffers reused across the whole run (`clear` keeps
        // capacity); the per-sample image Vecs come from the stream's
        // sample synthesis, which is outside the zero-alloc step scope.
        let mut images: Vec<Vec<f32>> = Vec::with_capacity(MAX_CHUNK);
        let mut labels: Vec<usize> = Vec::with_capacity(MAX_CHUNK);
        while t < self.cfg.samples {
            let mut end = self.cfg.samples.min(t + MAX_CHUNK);
            if self.cfg.drift.enabled() {
                end = end.min((t / drift_every + 1) * drift_every);
            }
            end = end.min((t / log_every + 1) * log_every);
            images.clear();
            labels.clear();
            for s in t..end {
                let smp = self.stream.sample(s as u64);
                images.push(smp.image);
                labels.push(smp.label);
            }
            for (loss, correct) in
                self.device.step_batch(&images, &labels)
            {
                self.metrics.record(correct, loss as f64);
            }
            t = end;
            if self.cfg.drift.enabled() && t % drift_every == 0 {
                self.device.drift();
            }
            if t % log_every == 0 {
                let w = self.device.max_cell_writes();
                self.metrics.log_point(t, w);
            }
        }
        assemble_report(
            &self.cfg,
            &self.device,
            &self.metrics,
            t0.elapsed().as_secs_f64(),
        )
    }
}

/// Assemble the final [`RunReport`] from a finished device + metrics
/// pair. Shared between [`Trainer::run`] and the sharded fleet engine
/// so per-device reports are field-identical by construction (only
/// `wall_secs` — excluded from Row output by the purity contract —
/// depends on the caller).
pub(crate) fn assemble_report(
    cfg: &RunConfig,
    device: &NativeDevice,
    metrics: &Metrics,
    wall_secs: f64,
) -> RunReport {
    let (commits, deferrals) = device.flush_stats();
    let total_writes = device.total_writes();
    // Constant-size telemetry sketches off the final device state. One
    // O(cells) pass — assemble_report already scans every cell for the
    // write maximum and totals, so this is the same order of work. The
    // (usually dominant) untouched cells fold into one push_n: the
    // histogram is order-free integer counts, so this is bit-identical
    // to pushing each zero individually.
    let mut telemetry = DeviceTelemetry {
        loss: metrics.loss_sketch.clone(),
        ..DeviceTelemetry::default()
    };
    let mut zero_cells = 0u64;
    for (l, arr) in device.arrays.iter().enumerate() {
        for (i, &w) in arr.cell_writes().iter().enumerate() {
            if w == 0 {
                zero_cells += 1;
            } else {
                telemetry.cell_writes.push(w as f64);
                telemetry.write_stream.insert_n(
                    fnv1a64_words(&[
                        WRITE_EVENT_TAG,
                        cfg.seed,
                        l as u64,
                        i as u64,
                    ]),
                    w,
                );
            }
        }
    }
    telemetry.cell_writes.push_n(0.0, zero_cells);
    RunReport {
        scheme: cfg.scheme.name().to_string(),
        env: cfg.env.name().to_string(),
        final_ema: metrics.acc_ema.get(),
        tail_acc: metrics.tail_acc(),
        overall_acc: metrics.overall_acc(),
        max_cell_writes: device.max_cell_writes(),
        total_writes,
        write_energy_pj: RunReport::energy_from_writes(
            total_writes,
            cfg.w_bits,
        ),
        endurance_used: device.max_cell_writes() as f64
            / crate::nvm::energy::ENDURANCE_WRITES,
        series: metrics.series.clone(),
        flush_commits: commits,
        flush_deferrals: deferrals,
        kappa_skips: device.kappa_skips,
        wall_secs,
        fault: device.fault_summary(),
        telemetry,
    }
}

/// Validation accuracy of parameters on the held-out partition.
/// Scoring forwards are independent (eval mode mutates nothing), so they
/// fan out across the shared worker pool.
pub fn validate(params: &Params, w_bits: u32, n: usize, seed: u64) -> f64 {
    let stream = OnlineStream::new(
        seed,
        Partition::Validation,
        crate::data::Env::Control,
    );
    let mut aux = model::AuxState::new();
    // burn in BN stats on a few validation samples (sequential: streaming)
    for t in 0..100.min(n) {
        let s = stream.sample(t as u64);
        model::forward(params, &mut aux, &s.image, 0.99, true, w_bits, true);
    }
    let aux = aux; // frozen for scoring
    // Each pool worker scores a contiguous slice with one retained
    // forward-only workspace and one AuxState clone (the clone only
    // satisfies forward's &mut signature — eval mode mutates nothing),
    // so per-sample scoring stays allocation-free. Forwards are
    // independent: the chunking changes nothing numerically.
    let correct: usize = workspace::map_samples(
        n,
        || aux.clone(),
        |t, ws, aux_w| {
            let s = stream.sample((1000 + t) as u64);
            model::forward_into(
                params, aux_w, &s.image, 0.99, true, w_bits, false, ws,
            );
            usize::from(model::argmax(&ws.caches.logits) == s.label)
        },
    )
    .into_iter()
    .sum();
    correct as f64 / n as f64
}

/// Convenience: run one scheme end-to-end (pretrain included).
pub fn run_scheme(mut cfg: RunConfig, scheme: Scheme) -> RunReport {
    cfg.scheme = scheme;
    Trainer::with_pretraining(cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lrt::Variant;

    #[test]
    fn short_run_all_schemes_complete() {
        let mut base = RunConfig::default();
        base.samples = 60;
        base.offline_samples = 120;
        base.log_every = 20;
        base.batch = [2, 2, 2, 2, 4, 4];
        let (params, aux) = pretrain(&base, false);
        for scheme in [
            Scheme::Inference,
            Scheme::BiasOnly,
            Scheme::Sgd,
            Scheme::Lrt { variant: Variant::Biased },
        ] {
            let mut cfg = base.clone();
            cfg.scheme = scheme;
            let mut tr = Trainer::new(cfg, params.clone(), aux.clone());
            let rep = tr.run();
            assert_eq!(rep.series.len(), 3);
            assert!((0.0..=1.0).contains(&rep.final_ema), "{rep:?}");
            if scheme == Scheme::Sgd {
                assert!(rep.total_writes > 0);
            }
            if scheme == Scheme::Inference {
                assert_eq!(rep.total_writes, 0);
            }
        }
    }

    #[test]
    fn pretrain_cache_is_transparent() {
        let mut cfg = RunConfig::default();
        cfg.offline_samples = 30;
        cfg.seed = 77;
        let (p1, a1) = pretrain_cached(&cfg);
        let (p2, _) = pretrain(&cfg, false);
        for i in 0..crate::nn::arch::N_LAYERS {
            assert_eq!(p1.w[i].data, p2.w[i].data);
        }
        let (p3, a3) = pretrain_cached(&cfg);
        assert_eq!(p1.w[0].data, p3.w[0].data);
        assert_eq!(a1.mn, a3.mn);
    }

    #[test]
    fn lrt_writes_far_fewer_than_sgd() {
        let mut base = RunConfig::default();
        base.samples = 40;
        base.offline_samples = 60;
        base.batch = [10, 10, 10, 10, 20, 20];
        let (params, aux) = pretrain(&base, false);
        let mut cfg_sgd = base.clone();
        cfg_sgd.scheme = Scheme::Sgd;
        let sgd = Trainer::new(cfg_sgd, params.clone(), aux.clone()).run();
        let mut cfg_lrt = base.clone();
        cfg_lrt.scheme = Scheme::Lrt { variant: Variant::Biased };
        let lrt = Trainer::new(cfg_lrt, params, aux).run();
        assert!(
            lrt.max_cell_writes * 4 < sgd.max_cell_writes.max(4),
            "lrt {} vs sgd {}",
            lrt.max_cell_writes,
            sgd.max_cell_writes
        );
    }
}
