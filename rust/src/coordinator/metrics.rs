//! Online metrics: per-sample accuracy EMA (0.999 like Fig. 6), NVM write
//! and energy accounting, and the run report benches print.

use crate::nvm::energy;
use crate::nvm::fault::FaultSummary;
use crate::util::json::Json;
use crate::util::sketch::{PowerSumSketch, QuantileSketch};
use crate::util::stats::Ema;
use crate::util::table::Row;

#[derive(Debug, Clone)]
pub struct Metrics {
    pub acc_ema: Ema,
    pub seen: usize,
    pub correct: usize,
    /// Correct count over the trailing `tail_window` samples.
    tail: std::collections::VecDeque<bool>,
    pub tail_window: usize,
    /// (step, ema accuracy, max cell writes) series for figures.
    pub series: Vec<(usize, f64, u64)>,
    pub loss_sum: f64,
    /// Constant-size summary of the per-sample loss stream: unlike
    /// `loss_sum` it keeps the tail (p99 loss), and unlike `series` it
    /// never grows with the stream. Bins are preallocated in `new`, so
    /// the hot-path `record` push stays allocation-free.
    pub loss_sketch: QuantileSketch,
}

impl Metrics {
    pub fn new(tail_window: usize) -> Metrics {
        Metrics {
            acc_ema: Ema::new(0.999),
            seen: 0,
            correct: 0,
            tail: std::collections::VecDeque::new(),
            tail_window,
            series: Vec::new(),
            loss_sum: 0.0,
            loss_sketch: QuantileSketch::for_loss(),
        }
    }

    pub fn record(&mut self, correct: bool, loss: f64) {
        self.seen += 1;
        self.correct += correct as usize;
        self.acc_ema.update(correct as u8 as f64);
        self.loss_sum += loss;
        self.loss_sketch.push(loss);
        self.tail.push_back(correct);
        if self.tail.len() > self.tail_window {
            self.tail.pop_front();
        }
    }

    pub fn log_point(&mut self, step: usize, max_writes: u64) {
        self.series.push((step, self.acc_ema.get(), max_writes));
    }

    /// Accuracy over the trailing window (the paper's "last 500 samples").
    pub fn tail_acc(&self) -> f64 {
        if self.tail.is_empty() {
            return 0.0;
        }
        self.tail.iter().filter(|&&b| b).count() as f64
            / self.tail.len() as f64
    }

    pub fn overall_acc(&self) -> f64 {
        if self.seen == 0 {
            return 0.0;
        }
        self.correct as f64 / self.seen as f64
    }

    /// Resident bytes of this tracker's heap buffers (tail window +
    /// logged series) — feeds the sharded fleet's per-record memory
    /// accounting, which sums actual buffer capacities.
    pub fn approx_bytes(&self) -> usize {
        self.tail.capacity() * std::mem::size_of::<bool>()
            + self.series.capacity()
                * std::mem::size_of::<(usize, f64, u64)>()
            + self.loss_sketch.approx_bytes()
    }
}

/// Constant-size per-device telemetry sketches (`util::sketch`), built
/// by `assemble_report` from the device's final state and merged up the
/// fleet's shard/wave tree. Total footprint is a few KB per device
/// regardless of samples seen or cells trained — the fleet engines ship
/// these instead of per-device rows.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceTelemetry {
    /// Distribution of per-cell write counts across all weight arrays
    /// (the wear histogram behind the fleet's p99 write columns).
    pub cell_writes: QuantileSketch,
    /// Power-sum quACK over hashed (seed, layer, cell) write events —
    /// five words that audit exactly which cells wrote, mergeable and
    /// subtractable across the fleet.
    pub write_stream: PowerSumSketch,
    /// Per-sample loss distribution (carried over from
    /// `Metrics::loss_sketch`).
    pub loss: QuantileSketch,
}

impl Default for DeviceTelemetry {
    fn default() -> DeviceTelemetry {
        DeviceTelemetry {
            cell_writes: QuantileSketch::for_counts(),
            write_stream: PowerSumSketch::new(),
            loss: QuantileSketch::for_loss(),
        }
    }
}

impl DeviceTelemetry {
    /// Fold another device's sketches into this one (exact integer
    /// merges: order never matters, so shard/wave partitioning cannot
    /// change the result).
    pub fn merge(&mut self, other: &DeviceTelemetry) {
        self.cell_writes.merge(&other.cell_writes);
        self.write_stream.merge(&other.write_stream);
        self.loss.merge(&other.loss);
    }

    /// Resident bytes — constant in stream length and population size.
    pub fn approx_bytes(&self) -> usize {
        self.cell_writes.approx_bytes()
            + self.write_stream.approx_bytes()
            + self.loss.approx_bytes()
    }
}

/// Final report of one online run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub scheme: String,
    pub env: String,
    pub final_ema: f64,
    pub tail_acc: f64,
    pub overall_acc: f64,
    /// Worst-case per-cell writes across all weight arrays (Fig. 6).
    pub max_cell_writes: u64,
    pub total_writes: u64,
    pub write_energy_pj: f64,
    pub endurance_used: f64,
    pub series: Vec<(usize, f64, u64)>,
    pub flush_commits: u64,
    pub flush_deferrals: u64,
    pub kappa_skips: u64,
    pub wall_secs: f64,
    /// Fault telemetry — `Some` only when a fault model was installed,
    /// so `FaultCfg::NONE` rows stay byte-identical to pre-fault runs.
    pub fault: Option<FaultSummary>,
    /// Mergeable sketch telemetry. Deliberately NOT emitted by
    /// `to_row` — per-run rows stay byte-identical to previous
    /// releases; the fleet engines merge these and publish percentile
    /// columns on their summary rows instead.
    pub telemetry: DeviceTelemetry,
}

impl RunReport {
    pub fn energy_from_writes(total_writes: u64, bits: u32) -> f64 {
        energy::write_energy_pj(total_writes, bits)
    }

    /// Structured emission for the sweep engine. Deliberately excludes
    /// `wall_secs`: rows must be a pure function of (config, seed) so a
    /// resumed sweep reproduces an uninterrupted one byte-for-byte.
    pub fn to_row(&self) -> Row {
        let row = Row::new()
            .str("scheme", &self.scheme)
            .str("env", &self.env)
            .num("acc_ema", self.final_ema, 3)
            .num("tail_acc", self.tail_acc, 3)
            .num("overall_acc", self.overall_acc, 3)
            .int("max_cell_writes", self.max_cell_writes)
            .int("total_writes", self.total_writes)
            .num("energy_uj", self.write_energy_pj / 1e6, 1)
            .int("flush_commits", self.flush_commits)
            .int("flush_deferrals", self.flush_deferrals)
            .int("kappa_skips", self.kappa_skips);
        // fault columns are appended ONLY when a fault model ran, so
        // FaultCfg::NONE output is byte-identical to pre-fault output
        match &self.fault {
            None => row,
            Some(f) => row
                .int("fault_stuck_cells", f.stuck_cells())
                .num("fault_defect_rate", f.defect_rate(), 6)
                .int("fault_factory_stuck", f.factory_stuck)
                .int("fault_retired", f.retired)
                .int("fault_wearouts", f.wearouts)
                .int("fault_retry_pulses", f.retry_pulses)
                .int("fault_pulses", f.pulses_attempted),
        }
    }

    /// The (step, accEMA, maxWrites) series as a JSON array, for
    /// `Row::detail` payloads.
    pub fn series_json(&self) -> Json {
        Json::Arr(
            self.series
                .iter()
                .map(|&(s, a, w)| {
                    Json::Arr(vec![
                        Json::Num(s as f64),
                        Json::Num(a),
                        Json::Num(w as f64),
                    ])
                })
                .collect(),
        )
    }

    pub fn summary_line(&self) -> String {
        let mut line = format!(
            "{:<13} {:<13} ema={:.3} tail={:.3} maxW={:<8} totW={:<10} \
             E={:.1}uJ flush={}({} defer) skips={} {:.1}s",
            self.scheme,
            self.env,
            self.final_ema,
            self.tail_acc,
            self.max_cell_writes,
            self.total_writes,
            self.write_energy_pj / 1e6,
            self.flush_commits,
            self.flush_deferrals,
            self.kappa_skips,
            self.wall_secs,
        );
        if let Some(f) = &self.fault {
            line.push_str(&format!(
                " faults[stuck={} ({:.4}) retired={} worn={} retries={}]",
                f.stuck_cells(),
                f.defect_rate(),
                f.retired,
                f.wearouts,
                f.retry_pulses,
            ));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_and_overall() {
        let mut m = Metrics::new(4);
        for b in [true, false, true, true, true, true] {
            m.record(b, 0.5);
        }
        assert!((m.overall_acc() - 5.0 / 6.0).abs() < 1e-12);
        assert_eq!(m.tail_acc(), 1.0); // last 4 all correct
        assert!(m.acc_ema.get() > 0.5);
    }

    #[test]
    fn report_row_is_structured_and_deterministic() {
        let rep = RunReport {
            scheme: "lrt-biased".into(),
            env: "control".into(),
            final_ema: 0.5,
            tail_acc: 0.25,
            overall_acc: 0.75,
            max_cell_writes: 3,
            total_writes: 30,
            write_energy_pj: 2e6,
            endurance_used: 0.0,
            series: vec![(10, 0.5, 3)],
            flush_commits: 2,
            flush_deferrals: 1,
            kappa_skips: 0,
            wall_secs: 1.23,
            fault: None,
            telemetry: DeviceTelemetry::default(),
        };
        let row = rep.to_row();
        assert_eq!(row.text("scheme"), Some("lrt-biased"));
        assert_eq!(row.text("acc_ema"), Some("0.500"));
        assert_eq!(row.text("max_cell_writes"), Some("3"));
        // wall time must never leak into structured rows
        assert!(row.value("wall_secs").is_none());
        assert!(!row.jsonl().contains("1.23"));
        // no fault model -> no fault columns at all (byte-identity)
        assert!(row.value("fault_stuck_cells").is_none());
        assert!(!row.jsonl().contains("fault"));
        assert_eq!(
            rep.series_json().to_string_compact(),
            "[[10,0.5,3]]"
        );
        // with a summary attached the counters surface
        let mut with = rep.clone();
        with.fault = Some(FaultSummary {
            cells: 100,
            factory_stuck: 4,
            retired: 1,
            wearouts: 0,
            retry_pulses: 7,
            pulses_attempted: 40,
            pulse_successes: 32,
        });
        let frow = with.to_row();
        assert_eq!(frow.text("fault_stuck_cells"), Some("5"));
        assert_eq!(frow.text("fault_defect_rate"), Some("0.050000"));
        assert_eq!(frow.text("fault_retry_pulses"), Some("7"));
        assert!(with.summary_line().contains("faults[stuck=5"));
    }

    #[test]
    fn series_logging() {
        let mut m = Metrics::new(10);
        m.record(true, 0.1);
        m.log_point(1, 42);
        assert_eq!(m.series, vec![(1, m.acc_ema.get(), 42)]);
    }
}
