//! Constant-size, deterministic, mergeable stream summaries for
//! fleet-scale telemetry (ROADMAP direction 2).
//!
//! At 10^5–10^6 simulated devices, shipping per-device rows is the
//! telemetry bottleneck, and naive streaming aggregates are numerical
//! traps: the one-pass sum-of-squares variance catastrophically cancels
//! for near-identical inputs (see `Moments`). Every summary here is
//!
//! * **constant-size** — `approx_bytes()` is independent of how many
//!   values were pushed (pinned by a property test);
//! * **deterministic** — pure IEEE-754 / integer arithmetic, no libm
//!   calls whose rounding could differ across platforms, so replayed
//!   runs are byte-identical;
//! * **mergeable** — `merge(sketch(A), sketch(B))` summarizes `A ∪ B`,
//!   so per-device summaries fold up the shard/wave tree. The integer
//!   sketches ([`QuantileSketch`], [`PowerSumSketch`]) merge *exactly*
//!   (bit-identical to sketching the union, associative, commutative);
//!   [`Moments`] merges up to f64 rounding (Chan's formula).

use crate::util::json::Json;

/// Streaming count/mean/variance accumulator: Welford's update with
/// Chan et al.'s parallel merge, computed relative to a per-sketch
/// origin (the first pushed value).
///
/// This replaces the one-pass sum/sum-of-squares formula
/// `(Σx² − n·mean²) / (n−1)`, which cancels catastrophically when the
/// spread is small against the magnitude: with 10^5 values near 0.9,
/// both accumulators sit near 10^5-scale where f64 spacing is ~10^-11,
/// so their difference is a multiple of that quantum — orders of
/// magnitude above the true sum of squares — and the customary
/// `.max(0.0)` clamp silently turns the resulting negative variance
/// into a fake 0.0. Welford's recurrence never subtracts two large
/// accumulators, and shifting by the origin keeps the running mean at
/// the *spread's* scale, so its per-step rounding is harmless too.
///
/// `m2` is a sum of `delta * delta2` terms whose factors always share a
/// sign (the new mean lies between the old mean and the sample), so the
/// variance is non-negative by construction — no masking clamp needed.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Moments {
    n: u64,
    /// First pushed value; all running state is relative to it.
    origin: f64,
    /// Running mean minus `origin`.
    mean_off: f64,
    /// Sum of squared deviations from the running mean.
    m2: f64,
}

impl Moments {
    pub fn new() -> Moments {
        Moments::default()
    }

    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.origin = x;
        }
        self.n += 1;
        let d = x - self.origin;
        let delta = d - self.mean_off;
        self.mean_off += delta / self.n as f64;
        let delta2 = d - self.mean_off;
        self.m2 += delta * delta2;
    }

    /// Chan et al. pairwise combine: after this, `self` summarizes the
    /// union of both streams. Exact in `n`; mean/variance agree with
    /// sequentially pushing the union up to f64 rounding.
    pub fn merge(&mut self, other: &Moments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        // re-express other's running mean relative to our origin (a
        // pure translation: m2 is origin-invariant)
        let other_off = (other.origin - self.origin) + other.mean_off;
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let n = n1 + n2;
        let delta = other_off - self.mean_off;
        self.mean_off += delta * (n2 / n);
        self.m2 += other.m2 + delta * delta * (n1 * n2 / n);
        self.n += other.n;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0.0 for an empty sketch, matching `stats::mean`).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.origin + self.mean_off
        }
    }

    /// Unbiased (n−1) variance; 0.0 for n < 2 like `stats::std_unbiased`.
    pub fn var_unbiased(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_unbiased(&self) -> f64 {
        self.var_unbiased().sqrt()
    }

    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
    }
}

/// Mergeable quantile sketch: a fixed-bin log-spaced histogram over the
/// declared range `[2^lo_exp, 2^hi_exp)`.
///
/// Layout is log-linear: each power-of-two octave splits into
/// `2^sub_bits` linearly spaced sub-bins, so the bin index is pure bit
/// manipulation of the f64 (exponent + top mantissa bits) — no `ln()`
/// whose libm rounding could differ across platforms. Two extra bins
/// catch underflow (x < 2^lo_exp — including zeros, negatives, and
/// NaN) and overflow (x ≥ 2^hi_exp, including +∞); the exact min/max
/// are tracked besides, so those ranks return exact endpoints.
///
/// **Error bound** (documented and property-tested): for pushed values
/// inside the declared range, `quantile(p)` never under-estimates the
/// exact nearest-rank quantile of the pushed multiset and
/// over-estimates it by at most a factor `1 + 2^-sub_bits` (one bin's
/// edge ratio). Rank handling itself is exact — the returned bin is
/// the first whose cumulative count covers `ceil(p/100 · n)`; only the
/// value within the bin is quantized. `quantile(100)` returns the
/// exact maximum. Out-of-range values clamp into the underflow /
/// overflow bins and report as the tracked min / max.
///
/// Merging requires identical `(lo_exp, hi_exp, sub_bits)` configs and
/// is exact: counts add as integers, so `sketch(A ∪ B)` is
/// bit-identical to `merge(sketch(A), sketch(B))` and merge order never
/// matters.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    lo_exp: i32,
    hi_exp: i32,
    sub_bits: u32,
    /// `counts[0]` underflow, `counts[last]` overflow, log-linear bins
    /// between — `(hi_exp - lo_exp) << sub_bits` of them.
    counts: Vec<u64>,
    n: u64,
    min: f64,
    max: f64,
}

/// 2^e as an f64, via bit assembly (e must be a normal exponent).
fn exp2i(e: i32) -> f64 {
    debug_assert!((-1022..=1023).contains(&e));
    f64::from_bits(((e + 1023) as u64) << 52)
}

impl QuantileSketch {
    /// Range `[2^lo_exp, 2^hi_exp)` with `2^sub_bits` sub-bins per
    /// octave (relative error bound `2^-sub_bits`).
    pub fn new(lo_exp: i32, hi_exp: i32, sub_bits: u32) -> QuantileSketch {
        assert!(lo_exp < hi_exp, "quantile sketch: empty range");
        assert!(
            (-1022..=1023).contains(&lo_exp)
                && (-1022..=1023).contains(&hi_exp),
            "quantile sketch: exponents must be normal"
        );
        assert!(sub_bits <= 16, "quantile sketch: sub_bits too large");
        let bins = ((hi_exp - lo_exp) as usize) << sub_bits;
        QuantileSketch {
            lo_exp,
            hi_exp,
            sub_bits,
            counts: vec![0; bins + 2],
            n: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Non-negative count-like streams (per-cell writes, virtual-µs
    /// latencies): range [1, 2^32), relative error ≤ 2^-3 = 12.5%.
    pub fn for_counts() -> QuantileSketch {
        QuantileSketch::new(0, 32, 3)
    }

    /// Probability-like streams (accuracy EMAs): range [2^-7, 1) with
    /// 1.0 landing exactly on the tracked max; rel. error ≤ 3.125%.
    pub fn for_unit() -> QuantileSketch {
        QuantileSketch::new(-7, 0, 5)
    }

    /// Per-sample loss streams (cross-entropy scale): range
    /// [2^-10, 2^6), relative error ≤ 2^-4 = 6.25%.
    pub fn for_loss() -> QuantileSketch {
        QuantileSketch::new(-10, 6, 4)
    }

    fn bin_index(&self, x: f64) -> usize {
        // NaN, negatives, zeros, and sub-range values all land in the
        // underflow bin (the comparison is false for NaN)
        if !(x >= exp2i(self.lo_exp)) {
            return 0;
        }
        if x >= exp2i(self.hi_exp) {
            return self.counts.len() - 1;
        }
        let bits = x.to_bits();
        let e = ((bits >> 52) & 0x7ff) as i32 - 1023;
        let sub =
            ((bits >> (52 - self.sub_bits)) & ((1u64 << self.sub_bits) - 1))
                as usize;
        1 + ((((e - self.lo_exp) as usize) << self.sub_bits) + sub)
    }

    /// Upper edge of in-range bin `b` (1-based over the log-linear
    /// bins). Exact dyadic arithmetic: deterministic across platforms.
    fn upper_edge(&self, b: usize) -> f64 {
        let li = b - 1;
        let s = (1usize << self.sub_bits) as f64;
        let e = self.lo_exp + (li >> self.sub_bits) as i32;
        let sub = (li & ((1 << self.sub_bits) - 1)) as f64;
        exp2i(e) * (1.0 + (sub + 1.0) / s)
    }

    pub fn push(&mut self, x: f64) {
        self.push_n(x, 1);
    }

    /// Push `m` copies of `x` in O(1) — bit-identical to `m` pushes
    /// (counts are order-free integer adds; min/max are idempotent).
    pub fn push_n(&mut self, x: f64, m: u64) {
        if m == 0 {
            return;
        }
        let b = self.bin_index(x);
        self.counts[b] += m;
        self.n += m;
        // f64::min/max ignore NaN, so a poisoned sample can inflate the
        // underflow count but never corrupts the tracked endpoints
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Merge another sketch over the same declared range (panics on a
    /// config mismatch — merging incompatible bins would be silent
    /// garbage).
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert!(
            (self.lo_exp, self.hi_exp, self.sub_bits)
                == (other.lo_exp, other.hi_exp, other.sub_bits),
            "quantile sketch merge: mismatched configs \
             ({},{},{}) vs ({},{},{})",
            self.lo_exp,
            self.hi_exp,
            self.sub_bits,
            other.lo_exp,
            other.hi_exp,
            other.sub_bits,
        );
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// p-th quantile estimate, p in [0, 100] (nearest-rank; 0.0 for an
    /// empty sketch). See the type docs for the error bound.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.n as f64).ceil().max(1.0);
        let rank = if rank >= self.n as f64 { self.n } else { rank as u64 };
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                if b == 0 {
                    return self.min;
                }
                if b == self.counts.len() - 1 {
                    return self.max;
                }
                // clamping to the exact max only tightens the bound
                return self.upper_edge(b).min(self.max);
            }
        }
        self.max
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Exact minimum pushed (`+∞` while empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Exact maximum pushed (`-∞` while empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Guaranteed relative over-estimation bound for in-range values:
    /// `2^-sub_bits`.
    pub fn rel_error_bound(&self) -> f64 {
        exp2i(-(self.sub_bits as i32))
    }

    /// Resident bytes — a function of the declared range only, never of
    /// the stream length.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.counts.capacity() * std::mem::size_of::<u64>()
    }
}

/// Modulus for [`PowerSumSketch`]: the Mersenne prime 2^61 − 1.
pub const POWER_SUM_MODULUS: u64 = (1u64 << 61) - 1;

/// Number of power sums a [`PowerSumSketch`] keeps.
pub const POWER_SUMS: usize = 4;

fn addmod(a: u64, b: u64) -> u64 {
    // both < 2^61, so the sum fits u64 with room to spare
    (a + b) % POWER_SUM_MODULUS
}

fn mulmod(a: u64, b: u64) -> u64 {
    ((a as u128 * b as u128) % POWER_SUM_MODULUS as u128) as u64
}

/// Power-sum stream sketch in the quACK style: the first
/// [`POWER_SUMS`] power sums of the inserted identifiers over the
/// prime modulus [`POWER_SUM_MODULUS`], plus an exact element count —
/// five words total, independent of stream length.
///
/// `sums[i] = Σ_x x^(i+1) mod P` over the inserted multiset. Sketches
/// merge by element-wise modular addition (exactly associative and
/// commutative: `sketch(A ∪ B) == merge(sketch(A), sketch(B))`
/// bit-for-bit), and a sketch of a sub-stream can be subtracted back
/// out ([`PowerSumSketch::sub`]) — the difference is the sketch of the
/// set difference, which is how quACKs decode missing elements. With
/// one element outstanding, [`PowerSumSketch::decode_one`] recovers it
/// from the first power sum alone.
///
/// Identifiers should be nonzero mod P (hashed ids in practice —
/// power sums of 0 contribute nothing beyond the count).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PowerSumSketch {
    sums: [u64; POWER_SUMS],
    count: u64,
}

impl PowerSumSketch {
    pub fn new() -> PowerSumSketch {
        PowerSumSketch::default()
    }

    pub fn insert(&mut self, x: u64) {
        self.insert_n(x, 1);
    }

    /// Insert `x` with multiplicity `m` in O(k) — identical to `m`
    /// separate inserts (the power sums scale linearly in multiplicity).
    pub fn insert_n(&mut self, x: u64, m: u64) {
        if m == 0 {
            return;
        }
        let v = x % POWER_SUM_MODULUS;
        let mm = m % POWER_SUM_MODULUS;
        let mut pw = v;
        for s in self.sums.iter_mut() {
            *s = addmod(*s, mulmod(mm, pw));
            pw = mulmod(pw, v);
        }
        self.count += m;
    }

    /// Element-wise modular add: `self` becomes the sketch of the
    /// multiset union.
    pub fn merge(&mut self, other: &PowerSumSketch) {
        for (s, o) in self.sums.iter_mut().zip(other.sums.iter()) {
            *s = addmod(*s, *o);
        }
        self.count += other.count;
    }

    /// Subtract a sketch of a sub-stream: `self` becomes the sketch of
    /// the multiset difference (caller guarantees `other` really is a
    /// sub-stream; counts saturate at zero otherwise).
    pub fn sub(&mut self, other: &PowerSumSketch) {
        for (s, o) in self.sums.iter_mut().zip(other.sums.iter()) {
            *s = addmod(*s, POWER_SUM_MODULUS - *o % POWER_SUM_MODULUS);
        }
        self.count = self.count.saturating_sub(other.count);
    }

    /// With exactly one element outstanding, the first power sum *is*
    /// that element (mod P).
    pub fn decode_one(&self) -> Option<u64> {
        if self.count == 1 {
            Some(self.sums[0])
        } else {
            None
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0 && self.sums.iter().all(|&s| s == 0)
    }

    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
    }

    /// JSONL payload: `[count, s1.., ]` with the power sums as hex
    /// strings (they exceed f64's 2^53 exact-integer range, so a
    /// `Json::Num` would corrupt them).
    pub fn to_json(&self) -> Json {
        let mut arr = vec![Json::Num(self.count as f64)];
        arr.extend(
            self.sums.iter().map(|s| Json::Str(format!("{s:016x}"))),
        );
        Json::Arr(arr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Rng;
    use crate::util::stats;

    // ---- Moments ----

    /// The headline bugfix regression: 10^5+ near-identical EMAs
    /// (0.9 + 1e-9·noise) destroy the old one-pass sum-of-squares
    /// formula, while Welford matches the two-pass reference.
    ///
    /// The old formula's failure here is *guaranteed*, not a flake:
    /// Σx² and n·mean² both land near 10^5, where consecutive f64s are
    /// ~1.45e-11 apart, so their difference is an exact multiple of
    /// that quantum while the true sum of squares is ~1e-14 — the
    /// computed difference is either 0 (clamped) or ≥ 1000× too large.
    #[test]
    fn welford_survives_catastrophic_cancellation() {
        let mut rng = Rng::new(42);
        let n = 120_000usize;
        let mut xs = Vec::with_capacity(n);
        for _ in 0..n {
            xs.push(0.9 + 1e-9 * rng.f64());
        }
        let mut m = Moments::new();
        let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
        for &x in &xs {
            m.push(x);
            sum += x;
            sumsq += x * x;
        }
        // the exact formula run_sharded_fleet used before this fix
        let nf = n as f64;
        let mean = sum / nf;
        let old_std =
            ((sumsq - nf * mean * mean).max(0.0) / (nf - 1.0)).sqrt();
        let exact = stats::std_unbiased(&xs);
        assert!(
            exact > 2e-10 && exact < 4e-10,
            "data sanity: exact std {exact}"
        );
        let old_rel = (old_std - exact).abs() / exact;
        assert!(
            old_std == 0.0 || old_rel > 5.0,
            "old formula should be catastrophically wrong: \
             old={old_std:e} exact={exact:e} rel={old_rel:e}"
        );
        // shifted Welford tracks the two-pass reference to ~1e-12
        // relative on this data (asserted with headroom)
        let new_rel = (m.std_unbiased() - exact).abs() / exact;
        assert!(
            new_rel < 1e-9,
            "welford diverged: new={:e} exact={exact:e} rel={new_rel:e}",
            m.std_unbiased()
        );
        assert!((m.mean() - mean).abs() / mean < 1e-12);
        assert_eq!(m.count(), n as u64);
    }

    #[test]
    fn moments_empty_and_single_conventions() {
        let m = Moments::new();
        assert_eq!(m.count(), 0);
        assert_eq!(m.mean(), 0.0);
        assert_eq!(m.std_unbiased(), 0.0);
        let mut m = Moments::new();
        m.push(0.7);
        assert_eq!(m.mean(), 0.7);
        assert_eq!(m.std_unbiased(), 0.0, "n < 2 convention");
        // merging an empty sketch is the identity, both ways
        let mut a = m;
        a.merge(&Moments::new());
        assert_eq!(a, m);
        let mut b = Moments::new();
        b.merge(&m);
        assert_eq!(b, m);
    }

    #[test]
    fn moments_matches_two_pass_reference() {
        check("moments vs two-pass", 32, |rng| {
            let n = 2 + rng.below(400);
            let scale = exp2i(rng.below(20) as i32 - 10);
            let xs: Vec<f64> =
                (0..n).map(|_| scale * (rng.f64() - 0.5)).collect();
            let mut m = Moments::new();
            for &x in &xs {
                m.push(x);
            }
            let (em, es) = (stats::mean(&xs), stats::std_unbiased(&xs));
            prop_assert!(
                (m.mean() - em).abs() <= 1e-12 * em.abs().max(scale),
                "mean {} vs {em}",
                m.mean()
            );
            prop_assert!(
                (m.std_unbiased() - es).abs() <= 1e-10 * es.abs().max(1e-300),
                "std {} vs {es}",
                m.std_unbiased()
            );
            Ok(())
        });
    }

    #[test]
    fn moments_merge_is_associative_commutative_and_union_consistent() {
        check("moments merge laws", 32, |rng| {
            let mk = |rng: &mut Rng, n: usize| {
                let mut m = Moments::new();
                let xs: Vec<f64> =
                    (0..n).map(|_| rng.f64() * 3.0 - 1.0).collect();
                for &x in &xs {
                    m.push(x);
                }
                (m, xs)
            };
            let (a, xa) = mk(rng, 1 + rng.below(50));
            let (b, xb) = mk(rng, 1 + rng.below(50));
            let (c, _) = mk(rng, 1 + rng.below(50));
            let close = |p: &Moments, q: &Moments| -> bool {
                p.count() == q.count()
                    && (p.mean() - q.mean()).abs() < 1e-12
                    && (p.var_unbiased() - q.var_unbiased()).abs() < 1e-12
            };
            // commutativity (within rounding)
            let mut ab = a;
            ab.merge(&b);
            let mut ba = b;
            ba.merge(&a);
            prop_assert!(close(&ab, &ba), "merge not commutative");
            // associativity (within rounding)
            let mut ab_c = ab;
            ab_c.merge(&c);
            let mut bc = b;
            bc.merge(&c);
            let mut a_bc = a;
            a_bc.merge(&bc);
            prop_assert!(close(&ab_c, &a_bc), "merge not associative");
            // merge vs sequentially pushing the union
            let mut seq = Moments::new();
            for &x in xa.iter().chain(xb.iter()) {
                seq.push(x);
            }
            prop_assert!(
                close(&ab, &seq),
                "merge {:?} vs sequential {:?}",
                ab,
                seq
            );
            // variance is non-negative by construction (no clamp)
            prop_assert!(ab.var_unbiased() >= 0.0, "negative variance");
            Ok(())
        });
    }

    // ---- QuantileSketch ----

    #[test]
    fn quantile_union_is_bit_identical_to_merge() {
        check("quantile merge = union", 32, |rng| {
            let gen = |rng: &mut Rng, n: usize| -> Vec<f64> {
                (0..n).map(|_| rng.f64() * 1e6).collect()
            };
            let xa = gen(rng, rng.below(200));
            let xb = gen(rng, rng.below(200));
            let xc = gen(rng, rng.below(200));
            let sk = |xs: &[f64]| {
                let mut s = QuantileSketch::for_counts();
                for &x in xs {
                    s.push(x);
                }
                s
            };
            let (a, b, c) = (sk(&xa), sk(&xb), sk(&xc));
            // union vs merge: bit-identical struct equality
            let mut union: Vec<f64> = xa.clone();
            union.extend(&xb);
            let mut ab = a.clone();
            ab.merge(&b);
            prop_assert!(ab == sk(&union), "merge != sketch of union");
            // exactly commutative and associative
            let mut ba = b.clone();
            ba.merge(&a);
            prop_assert!(ab == ba, "quantile merge not commutative");
            let mut ab_c = ab.clone();
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            prop_assert!(ab_c == a_bc, "quantile merge not associative");
            Ok(())
        });
    }

    #[test]
    fn quantile_error_bound_vs_exact_sort() {
        check("quantile error bound", 32, |rng| {
            let n = 1 + rng.below(500);
            // in-range data for for_counts(): [1, 2^32)
            let xs: Vec<f64> = (0..n)
                .map(|_| 1.0 + rng.f64() * rng.f64() * 1e6)
                .collect();
            let mut s = QuantileSketch::for_counts();
            for &x in &xs {
                s.push(x);
            }
            let mut sorted = xs.clone();
            sorted.sort_by(f64::total_cmp);
            let gamma = 1.0 + s.rel_error_bound();
            for &p in &[1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9] {
                let rank =
                    ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
                let exact = sorted[rank.min(n) - 1];
                let est = s.quantile(p);
                prop_assert!(
                    est >= exact * (1.0 - 1e-12),
                    "p{p}: est {est} under-estimates exact {exact}"
                );
                prop_assert!(
                    est <= exact * gamma * (1.0 + 1e-12),
                    "p{p}: est {est} above bound {} (exact {exact})",
                    exact * gamma
                );
            }
            // p=100 is the tracked max, exactly
            prop_assert!(
                s.quantile(100.0) == sorted[n - 1],
                "p100 not exact max"
            );
            Ok(())
        });
    }

    #[test]
    fn quantile_handles_zeros_out_of_range_and_nan() {
        let mut s = QuantileSketch::for_counts();
        // zeros dominate: low quantiles return the exact min (0.0)
        s.push_n(0.0, 70);
        s.push_n(100.0, 30);
        assert_eq!(s.count(), 100);
        assert_eq!(s.quantile(50.0), 0.0);
        assert!(s.quantile(99.0) >= 100.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 100.0);
        // overflow clamps to the tracked max
        s.push(1e12);
        assert_eq!(s.quantile(100.0), 1e12);
        // NaN inflates the underflow count but not the endpoints
        s.push(f64::NAN);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 1e12);
        // empty sketch convention
        assert_eq!(QuantileSketch::for_unit().quantile(50.0), 0.0);
    }

    #[test]
    fn push_n_equals_repeated_push() {
        let mut a = QuantileSketch::for_loss();
        let mut b = QuantileSketch::for_loss();
        for &(x, m) in &[(0.01, 5u64), (1.7, 3), (0.0, 2), (64.0, 1)] {
            a.push_n(x, m);
            for _ in 0..m {
                b.push(x);
            }
        }
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "mismatched configs")]
    fn quantile_merge_rejects_mismatched_configs() {
        let mut a = QuantileSketch::for_counts();
        a.merge(&QuantileSketch::for_unit());
    }

    // ---- PowerSumSketch ----

    #[test]
    fn power_sum_modular_identities() {
        check("power-sum identities", 32, |rng| {
            let gen = |rng: &mut Rng, n: usize| -> Vec<u64> {
                (0..n).map(|_| rng.next_u64()).collect()
            };
            let xa = gen(rng, rng.below(64));
            let xb = gen(rng, 1 + rng.below(64));
            let xc = gen(rng, rng.below(64));
            let sk = |xs: &[u64]| {
                let mut s = PowerSumSketch::new();
                for &x in xs {
                    s.insert(x);
                }
                s
            };
            let (a, b, c) = (sk(&xa), sk(&xb), sk(&xc));
            // union == merge, bit-identical
            let mut union = xa.clone();
            union.extend(&xb);
            let mut ab = a;
            ab.merge(&b);
            prop_assert!(ab == sk(&union), "merge != sketch of union");
            // exactly commutative and associative
            let mut ba = b;
            ba.merge(&a);
            prop_assert!(ab == ba, "power-sum merge not commutative");
            let mut ab_c = ab;
            ab_c.merge(&c);
            let mut bc = b;
            bc.merge(&c);
            let mut a_bc = a;
            a_bc.merge(&bc);
            prop_assert!(ab_c == a_bc, "power-sum merge not associative");
            // subtracting a sub-stream recovers the rest exactly
            let mut diff = ab;
            diff.sub(&a);
            prop_assert!(diff == b, "sub(A∪B, A) != B");
            // multiplicity: insert_n(x, m) == m inserts of x
            let x = rng.next_u64();
            let m = 1 + rng.below(100) as u64;
            let mut by_n = PowerSumSketch::new();
            by_n.insert_n(x, m);
            let mut by_loop = PowerSumSketch::new();
            for _ in 0..m {
                by_loop.insert(x);
            }
            prop_assert!(by_n == by_loop, "insert_n != repeated insert");
            Ok(())
        });
    }

    #[test]
    fn power_sum_decodes_a_single_outstanding_element() {
        let mut fleet = PowerSumSketch::new();
        let ids = [0xDEAD_BEEFu64, 0xFEED_FACE, 0x0123_4567_89AB_CDEF];
        for &id in &ids {
            fleet.insert(id);
        }
        // a straggler reported everything but the last write
        let mut partial = PowerSumSketch::new();
        partial.insert(ids[0]);
        partial.insert(ids[1]);
        let mut missing = fleet;
        missing.sub(&partial);
        assert_eq!(
            missing.decode_one(),
            Some(ids[2] % POWER_SUM_MODULUS)
        );
        assert_eq!(fleet.decode_one(), None, "3 outstanding: no decode");
        // empty sketch and exact cancellation
        let mut zero = fleet;
        zero.sub(&fleet);
        assert!(zero.is_empty());
    }

    // ---- constant size ----

    #[test]
    fn approx_bytes_constant_in_stream_length() {
        let mut m = Moments::new();
        let mut q = QuantileSketch::for_counts();
        let mut p = PowerSumSketch::new();
        let (b_m, b_q, b_p) =
            (m.approx_bytes(), q.approx_bytes(), p.approx_bytes());
        let mut rng = Rng::new(7);
        for i in 0..10_000u64 {
            m.push(rng.f64());
            q.push(rng.f64() * 1e9);
            p.insert(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        }
        assert_eq!(m.approx_bytes(), b_m);
        assert_eq!(q.approx_bytes(), b_q);
        assert_eq!(p.approx_bytes(), b_p);
        // and a few words really means a few words
        assert!(b_p <= 48, "power-sum sketch grew: {b_p} B");
    }
}
