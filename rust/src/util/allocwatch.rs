//! Heap-allocation instrumentation for the zero-alloc hot-path contract.
//!
//! The training hot loop (PR 4) is allocation-free in steady state: after
//! one warm-up step every buffer lives in a retained [`Workspace`] /
//! per-state scratch, and a step performs **zero** heap allocations on
//! the stepping thread. This module is how tests *prove* that instead of
//! asserting it in a comment:
//!
//! - [`CountingAlloc`] is a `GlobalAlloc` wrapper around the `System`
//!   allocator that bumps a **thread-local** counter on every `alloc` /
//!   `realloc` / `alloc_zeroed`. It is *not* installed by the library —
//!   a test binary opts in with
//!   `#[global_allocator] static A: CountingAlloc = CountingAlloc;`
//!   so the shipped library and CLI never pay the bookkeeping. (This is
//!   the `cfg`-free form of a debug-gated watcher: the gate is which
//!   binary links it; the CI leg drives it with `LRT_ALLOC_WATCH=1`.)
//! - [`counted`] runs a closure and returns how many allocations it made
//!   on the current thread. Reporting is gated by `LRT_ALLOC_WATCH`:
//!   unset or any value but `0` means live (the CI leg sets `1`
//!   explicitly); `LRT_ALLOC_WATCH=0` turns [`counted`] into a
//!   pass-through that reports 0, so the env var genuinely toggles the
//!   watcher without a rebuild. (The gate is read at *reporting* time,
//!   never inside the allocator — reading an env var allocates.)
//! - [`pause`] suspends counting on the current thread until the guard
//!   drops. The kernel pool uses it around its scoped-thread fan-out:
//!   spawning OS threads heap-allocates by nature (stacks, join state),
//!   and that machinery is pool overhead, not hot-path traffic. User
//!   closures the fan-out runs on the *calling* thread are re-counted
//!   via [`unpause`], so the exemption covers exactly the machinery.
//!   The single-threaded leg of `tests/alloc_steady_state.rs` runs with
//!   the pool pinned to 1 worker, where no pause scope is ever entered,
//!   so the strong zero-alloc claim is proven unexempted there; the
//!   multi-threaded leg proves the engine layers stay allocation-free
//!   while the pool fans out.
//!
//! The counter is a `const`-initialized thread-local `Cell`, so reading
//! or bumping it never allocates (no lazy TLS initialization), which is
//! what makes it safe to touch from inside the allocator itself.
//!
//! [`Workspace`]: crate::nn::workspace::Workspace

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
    static PAUSED: Cell<u32> = const { Cell::new(0) };
}

/// `System`-backed allocator counting per-thread allocation events.
/// Install in a test binary with `#[global_allocator]`.
pub struct CountingAlloc;

#[inline]
fn bump() {
    // `try_with`: TLS may be mid-destruction during thread teardown;
    // missing those events is fine (they are not hot-path traffic).
    let _ = ALLOCS.try_with(|c| {
        let _ = PAUSED.try_with(|p| {
            if p.get() == 0 {
                c.set(c.get() + 1);
            }
        });
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

/// Allocation events recorded on this thread so far (only meaningful in
/// a binary that installed [`CountingAlloc`]; always 0 elsewhere).
pub fn count() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Whether the watcher reports: true unless `LRT_ALLOC_WATCH=0`.
/// Counting itself always runs in an instrumented binary (it is a
/// thread-local bump — reading the env var from the allocator would
/// itself allocate); this gates what [`counted`] reports.
pub fn enabled() -> bool {
    use std::sync::OnceLock;
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        std::env::var("LRT_ALLOC_WATCH").map_or(true, |v| v != "0")
    })
}

/// Run `f` and return how many heap allocations it performed on the
/// current thread (paused scopes excluded; reports 0 when the watcher
/// is disabled via `LRT_ALLOC_WATCH=0`).
pub fn counted<T>(f: impl FnOnce() -> T) -> (T, u64) {
    if !enabled() {
        return (f(), 0);
    }
    let before = count();
    let out = f();
    (out, count() - before)
}

/// Suspends counting on this thread until the guard drops. Nestable.
pub struct PauseGuard(());

impl Drop for PauseGuard {
    fn drop(&mut self) {
        let _ = PAUSED.try_with(|p| p.set(p.get() - 1));
    }
}

/// Exempt a scope from allocation counting — the kernel pool wraps its
/// scoped-thread spawn machinery with this (see module docs for why
/// that exemption is honest).
pub fn pause() -> PauseGuard {
    PAUSED.with(|p| p.set(p.get() + 1));
    PauseGuard(())
}

/// Re-enables counting inside a paused scope until the guard drops
/// (restores the enclosing pause depth). `run_scoped` wraps each user
/// closure it executes on the calling thread with this, so the pause
/// exempts only the pool's own machinery.
pub struct UnpauseGuard {
    prev: u32,
}

impl Drop for UnpauseGuard {
    fn drop(&mut self) {
        let _ = PAUSED.try_with(|p| p.set(self.prev));
    }
}

pub fn unpause() -> UnpauseGuard {
    let prev = PAUSED.with(|p| {
        let v = p.get();
        p.set(0);
        v
    });
    UnpauseGuard { prev }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the unit-test binary does not install CountingAlloc (that
    // would tax every other test in it); these tests cover the counter
    // plumbing, and `tests/alloc_steady_state.rs` covers real counting.

    #[test]
    fn pause_nests_and_restores() {
        {
            let _a = pause();
            {
                let _b = pause();
                PAUSED.with(|p| assert_eq!(p.get(), 2));
            }
            PAUSED.with(|p| assert_eq!(p.get(), 1));
        }
        PAUSED.with(|p| assert_eq!(p.get(), 0));
    }

    #[test]
    fn counted_is_zero_without_installed_allocator() {
        let ((), n) = counted(|| {
            let v: Vec<u8> = Vec::with_capacity(64);
            std::hint::black_box(&v);
        });
        assert_eq!(n, 0, "counter must be inert unless installed");
    }
}
