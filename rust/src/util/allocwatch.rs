//! Heap-allocation instrumentation for the zero-alloc hot-path contract.
//!
//! The training hot loop (PR 4) is allocation-free in steady state: after
//! one warm-up step every buffer lives in a retained [`Workspace`] /
//! per-state scratch, and a step performs **zero** heap allocations on
//! the stepping thread. Since PR 5 the claim is **absolute on every
//! thread**: the kernel layer dispatches onto a persistent parked worker
//! pool (`tensor::pool`) whose job submission is itself allocation-free
//! (retained per-worker slots, futex-backed latches, no boxed closures),
//! so the old `pause()`/`unpause()` exemption around thread-spawn
//! machinery is gone — spawning only ever happens at lazy pool start,
//! which is warm-up traffic by definition. This module is how tests
//! *prove* that instead of asserting it in a comment:
//!
//! - [`CountingAlloc`] is a `GlobalAlloc` wrapper around the `System`
//!   allocator that bumps a **thread-local** counter on every `alloc` /
//!   `realloc` / `alloc_zeroed`. It is *not* installed by the library —
//!   a test binary opts in with
//!   `#[global_allocator] static A: CountingAlloc = CountingAlloc;`
//!   so the shipped library and CLI never pay the bookkeeping. (This is
//!   the `cfg`-free form of a debug-gated watcher: the gate is which
//!   binary links it; the CI leg drives it with `LRT_ALLOC_WATCH=1`.)
//! - [`counted`] runs a closure and returns how many allocations it made
//!   on the current thread. Reporting is gated by `LRT_ALLOC_WATCH`:
//!   unset or any value but `0` means live (the CI leg sets `1`
//!   explicitly); `LRT_ALLOC_WATCH=0` turns [`counted`] into a
//!   pass-through that reports 0, so the env var genuinely toggles the
//!   watcher without a rebuild. (The gate is read at *reporting* time,
//!   never inside the allocator — reading an env var allocates.)
//!
//! Because the counter is per-thread, [`counted`] composes across the
//! pool: the stepping thread proves its own steady state, and a fan-out
//! whose closures call [`counted`] proves the workers' steady state too
//! (`tests/alloc_steady_state.rs` asserts both, for every scheme x ISA
//! tier x pool regime, with no exemption anywhere).
//!
//! The counter is a `const`-initialized thread-local `Cell`, so reading
//! or bumping it never allocates (no lazy TLS initialization), which is
//! what makes it safe to touch from inside the allocator itself.
//!
//! [`Workspace`]: crate::nn::workspace::Workspace

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// `System`-backed allocator counting per-thread allocation events.
/// Install in a test binary with `#[global_allocator]`.
pub struct CountingAlloc;

#[inline]
fn bump() {
    // `try_with`: TLS may be mid-destruction during thread teardown;
    // missing those events is fine (they are not hot-path traffic).
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

/// Allocation events recorded on this thread so far (only meaningful in
/// a binary that installed [`CountingAlloc`]; always 0 elsewhere).
pub fn count() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Whether the watcher reports: true unless `LRT_ALLOC_WATCH=0`.
/// Counting itself always runs in an instrumented binary (it is a
/// thread-local bump — reading the env var from the allocator would
/// itself allocate); this gates what [`counted`] reports. The gate is
/// cached in a `OnceLock`: call [`enabled`] (or [`counted`]) once per
/// thread-of-interest during warm-up if the first read's env allocation
/// would otherwise land inside a measured region.
pub fn enabled() -> bool {
    use std::sync::OnceLock;
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        std::env::var("LRT_ALLOC_WATCH").map_or(true, |v| v != "0")
    })
}

/// Run `f` and return how many heap allocations it performed on the
/// current thread (reports 0 when the watcher is disabled via
/// `LRT_ALLOC_WATCH=0`). There is no pause/exemption mechanism: every
/// allocation on this thread inside `f` counts, including any made by
/// kernel-pool dispatch (which is exactly why the pool's submission
/// path had to become allocation-free).
pub fn counted<T>(f: impl FnOnce() -> T) -> (T, u64) {
    if !enabled() {
        return (f(), 0);
    }
    let before = count();
    let out = f();
    (out, count() - before)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the unit-test binary does not install CountingAlloc (that
    // would tax every other test in it); these tests cover the counter
    // plumbing, and `tests/alloc_steady_state.rs` covers real counting.

    #[test]
    fn counted_is_zero_without_installed_allocator() {
        let ((), n) = counted(|| {
            let v: Vec<u8> = Vec::with_capacity(64);
            std::hint::black_box(&v);
        });
        assert_eq!(n, 0, "counter must be inert unless installed");
    }

    #[test]
    fn counted_nests_and_counts_are_monotone() {
        let before = count();
        let ((inner_result, inner_n), outer_n) =
            counted(|| counted(|| std::hint::black_box(2 + 2)));
        assert_eq!(inner_result, 4);
        // inert binary: both frames report zero, and the raw counter
        // never went backwards
        assert_eq!(inner_n, 0);
        assert_eq!(outer_n, 0);
        assert!(count() >= before);
    }
}
