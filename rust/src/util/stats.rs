//! Small statistics helpers shared by metrics, benches, and experiments.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased (n-1) standard deviation, matching the paper's tables.
pub fn std_unbiased(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Exponential moving average tracker (the paper plots EMA(0.999) of
/// per-sample online accuracy in Figure 6).
#[derive(Debug, Clone)]
pub struct Ema {
    pub decay: f64,
    value: f64,
    weight: f64,
}

impl Ema {
    pub fn new(decay: f64) -> Self {
        Ema { decay, value: 0.0, weight: 0.0 }
    }

    pub fn update(&mut self, x: f64) {
        self.value = self.decay * self.value + (1.0 - self.decay) * x;
        self.weight = self.decay * self.weight + (1.0 - self.decay);
    }

    /// Bias-corrected estimate (exact average until the window fills).
    pub fn get(&self) -> f64 {
        if self.weight == 0.0 {
            0.0
        } else {
            self.value / self.weight
        }
    }
}

/// p-th percentile (linear interpolation), p in [0, 100].
///
/// Sorts by IEEE-754 total order (`f64::total_cmp`), so NaN input is
/// well-defined instead of a panic: -NaN sorts below every number and
/// +NaN above, skewing the affected tail — a poisoned sample shows up
/// as a distorted percentile, never as a crash of the caller (the
/// serve latency report and `grads.rs` feed this directly).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    interpolate(&v, p)
}

/// Several percentiles of the same data with a single clone + sort
/// (the serve report reads p50/p99/p999 off one latency vector; three
/// `percentile` calls meant three sorts). Each result is bit-identical
/// to the corresponding single-`percentile` call, including the NaN
/// total-order behavior documented there; empty input yields 0.0 for
/// every requested rank.
pub fn percentiles(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; ps.len()];
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    ps.iter().map(|&p| interpolate(&v, p)).collect()
}

/// Linear interpolation into already-sorted, non-empty data.
fn interpolate(v: &[f64], p: f64) -> f64 {
    let idx = (p / 100.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_unbiased(&xs) - 2.13809).abs() < 1e-4);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_unbiased(&[1.0]), 0.0);
    }

    #[test]
    fn ema_converges_and_bias_corrects() {
        let mut e = Ema::new(0.9);
        e.update(1.0);
        assert!((e.get() - 1.0).abs() < 1e-12, "bias correction");
        for _ in 0..200 {
            e.update(3.0);
        }
        assert!((e.get() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_survives_nan_input() {
        // regression: partial_cmp().unwrap() panicked here
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        let p0 = percentile(&xs, 0.0);
        assert_eq!(p0, 1.0, "+NaN must sort above the numbers");
        assert!(percentile(&xs, 100.0).is_nan());
        // the untouched tail still reads clean values
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        // all-NaN input: defined (NaN), not a panic
        assert!(percentile(&[f64::NAN, f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn percentile_negative_nan_sorts_low() {
        let neg_nan = f64::from_bits(0xFFF8_0000_0000_0000);
        let xs = [neg_nan, 5.0, 7.0];
        assert!(percentile(&xs, 0.0).is_nan());
        assert_eq!(percentile(&xs, 100.0), 7.0);
    }

    #[test]
    fn percentiles_matches_percentile_bitwise() {
        let xs = [9.0, 1.0, 4.0, 4.0, 2.5, 8.0, 0.5];
        let ps = [0.0, 12.5, 50.0, 99.0, 99.9, 100.0];
        let batch = percentiles(&xs, &ps);
        assert_eq!(batch.len(), ps.len());
        for (i, &p) in ps.iter().enumerate() {
            assert!(
                batch[i].to_bits() == percentile(&xs, p).to_bits(),
                "p{p}: batch {} vs single {}",
                batch[i],
                percentile(&xs, p)
            );
        }
        // empty input and NaN contract carry over
        assert_eq!(percentiles(&[], &ps), vec![0.0; ps.len()]);
        let poisoned = [3.0, f64::NAN, 1.0, 2.0];
        let got = percentiles(&poisoned, &[0.0, 50.0, 100.0]);
        assert_eq!(got[0], 1.0);
        assert!((got[1] - 2.5).abs() < 1e-12);
        assert!(got[2].is_nan());
    }
}
