//! Tiny property-testing harness (the vendored crate set has no proptest).
//!
//! `check(name, cases, f)` runs `f` against `cases` independently seeded
//! RNGs. On failure it panics with the failing seed so the case can be
//! replayed with `LRT_PROP_SEED=<seed>`; set `LRT_PROP_CASES` to raise the
//! case count locally.

use super::rng::Rng;

/// Number of cases, overridable via `LRT_PROP_CASES`.
pub fn case_count(default: usize) -> usize {
    std::env::var("LRT_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Run `f` for `cases` seeds; `f` returns Err(description) on violation.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(seed) = std::env::var("LRT_PROP_SEED") {
        let seed: u64 = seed.parse().expect("bad LRT_PROP_SEED");
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed (replay seed {seed}): {msg}");
        }
        return;
    }
    for case in 0..case_count(cases) {
        let seed = 0x5EED_0000_u64 + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} \
                 (replay with LRT_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert helper producing property-style error strings.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 10, |rng| {
            n += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert!(n >= 10);
    }

    #[test]
    #[should_panic(expected = "replay with LRT_PROP_SEED")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }
}
