//! Zero-dependency command-line argument parsing.
//!
//! Grammar: `lrt-nvm <subcommand> [--key value | --key=value | --flag]...`
//! (the vendored crate set has no `clap`). A token after `--key` is
//! consumed as the value unless it is itself option-like (`--` followed
//! by an alphabetic key), so `--delta --0.5` reads the negative-flag-
//! looking `--0.5` as a value; `--key=value` sidesteps the question for
//! arbitrary values.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut args = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.command = it.next().unwrap();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                let is_flag = match it.peek() {
                    None => true,
                    Some(next) => is_option_like(next),
                };
                if is_flag {
                    args.options.insert(key.to_string(), "true".to_string());
                } else {
                    args.options.insert(key.to_string(), it.next().unwrap());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str, default: &str) -> String {
        self.options
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn f64_opt(&self, key: &str, default: f64) -> f64 {
        self.options
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn usize_opt(&self, key: &str, default: usize) -> usize {
        self.options
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_opt(&self, key: &str, default: u64) -> u64 {
        self.options
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

/// True when `tok` is an option token (`--key` / `--key=...` with an
/// alphabetic key start) rather than a value that merely begins with
/// `--` (e.g. `--0.5`).
fn is_option_like(tok: &str) -> bool {
    match tok.strip_prefix("--") {
        Some(rest) => rest
            .chars()
            .next()
            .map_or(true, |c| c.is_ascii_alphabetic()),
        None => false,
    }
}

/// `LRT_FULL=1` switches benches from CI-sized to paper-scale workloads.
pub fn full_scale() -> bool {
    std::env::var("LRT_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["adapt", "--env", "drift", "--samples", "2000"]);
        assert_eq!(a.command, "adapt");
        assert_eq!(a.str_opt("env", "control"), "drift");
        assert_eq!(a.usize_opt("samples", 0), 2000);
        assert_eq!(a.f64_opt("lr", 0.01), 0.01);
    }

    #[test]
    fn flags() {
        let a = parse(&["bench", "--verbose", "--n", "3", "--quick"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("quick"));
        assert_eq!(a.usize_opt("n", 0), 3);
        assert!(!a.flag("absent"));
    }

    #[test]
    fn positional() {
        let a = parse(&["run", "file.hlo", "--x", "1"]);
        assert_eq!(a.positional, vec!["file.hlo"]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.command, "");
        assert!(a.flag("help"));
    }

    #[test]
    fn key_equals_value_syntax() {
        let a = parse(&["run", "fig7", "--samples=500", "--label=--weird", "--quick"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.positional, vec!["fig7"]);
        assert_eq!(a.usize_opt("samples", 0), 500);
        // `=` keeps arbitrary values intact, even option-looking ones
        assert_eq!(a.str_opt("label", ""), "--weird");
        assert!(a.flag("quick"));
    }

    #[test]
    fn negative_flag_looking_value_is_a_value() {
        let a = parse(&["run", "--delta", "--0.5", "--seeds", "3"]);
        assert_eq!(a.str_opt("delta", ""), "--0.5");
        assert_eq!(a.usize_opt("seeds", 0), 3);
        // a real option after a key still makes the key a flag
        let b = parse(&["run", "--verbose", "--seeds", "3"]);
        assert!(b.flag("verbose"));
        assert_eq!(b.usize_opt("seeds", 0), 3);
    }
}
