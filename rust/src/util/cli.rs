//! Zero-dependency command-line argument parsing.
//!
//! Grammar: `lrt-nvm <subcommand> [--key value | --flag]...`
//! (the vendored crate set has no `clap`).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut args = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.command = it.next().unwrap();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                let is_flag = match it.peek() {
                    None => true,
                    Some(next) => next.starts_with("--"),
                };
                if is_flag {
                    args.options.insert(key.to_string(), "true".to_string());
                } else {
                    args.options.insert(key.to_string(), it.next().unwrap());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str, default: &str) -> String {
        self.options
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn f64_opt(&self, key: &str, default: f64) -> f64 {
        self.options
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn usize_opt(&self, key: &str, default: usize) -> usize {
        self.options
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_opt(&self, key: &str, default: u64) -> u64 {
        self.options
            .get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

/// `LRT_FULL=1` switches benches from CI-sized to paper-scale workloads.
pub fn full_scale() -> bool {
    std::env::var("LRT_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["adapt", "--env", "drift", "--samples", "2000"]);
        assert_eq!(a.command, "adapt");
        assert_eq!(a.str_opt("env", "control"), "drift");
        assert_eq!(a.usize_opt("samples", 0), 2000);
        assert_eq!(a.f64_opt("lr", 0.01), 0.01);
    }

    #[test]
    fn flags() {
        let a = parse(&["bench", "--verbose", "--n", "3", "--quick"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("quick"));
        assert_eq!(a.usize_opt("n", 0), 3);
        assert!(!a.flag("absent"));
    }

    #[test]
    fn positional() {
        let a = parse(&["run", "file.hlo", "--x", "1"]);
        assert_eq!(a.positional, vec!["file.hlo"]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.command, "");
        assert!(a.flag("help"));
    }
}
