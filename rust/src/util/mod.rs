//! Hand-rolled infrastructure: the offline vendored crate set lacks
//! serde/clap/rand/proptest/criterion, so their minimal equivalents live
//! here (DESIGN.md section 6, substitution 5).

pub mod allocwatch;
pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sketch;
pub mod stats;
pub mod table;
