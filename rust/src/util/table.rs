//! Aligned ASCII tables for the bench harnesses (no criterion offline);
//! each bench prints the same rows/series as the paper's table or figure.

/// Simple column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let ncol = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                line.push_str(&format!("{cell:>w$}  ", w = w));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &widths));
            out.push('\n');
            out.push_str(
                &widths
                    .iter()
                    .map(|w| "-".repeat(*w))
                    .collect::<Vec<_>>()
                    .join("  "),
            );
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// `mean ± std` cell formatting used throughout the paper's tables.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:+.1} ± {std:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["alg", "acc"]);
        t.row(vec!["SGD", "+0.3"]);
        t.row(vec!["Biased LRT", "+6.5"]);
        let s = t.render();
        assert!(s.contains("alg"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[3].contains("Biased LRT"));
    }

    #[test]
    fn pm_format() {
        assert_eq!(pm(6.5, 0.7), "+6.5 ± 0.7");
        assert_eq!(pm(-3.9, 0.8), "-3.9 ± 0.8");
    }
}
