//! Aligned ASCII tables for the bench harnesses (no criterion offline),
//! plus the structured `Row` record the sweep engine streams: every
//! experiment cell emits `Row`s, rendered here for humans (`render_rows`)
//! and serialized as JSON Lines for machines (`Row::jsonl`).

use crate::util::json::{self, Json};

/// Simple column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let ncol = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                line.push_str(&format!("{cell:>w$}  ", w = w));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &widths));
            out.push('\n');
            out.push_str(
                &widths
                    .iter()
                    .map(|w| "-".repeat(*w))
                    .collect::<Vec<_>>()
                    .join("  "),
            );
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// `mean ± std` cell formatting used throughout the paper's tables.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:+.1} ± {std:.1}")
}

// ---------------------------------------------------------------------
// Structured result rows
// ---------------------------------------------------------------------

/// One structured result record: an ordered list of (column, value)
/// fields. Fields carry both a typed JSON value (for the results file)
/// and a display string (for the aligned table), so a float keeps its
/// experiment-defined precision in print while staying a number on the
/// wire. Fields added with `detail` are JSON-only — bulky payloads like
/// accuracy series that would wreck a table.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Row {
    fields: Vec<Field>,
}

#[derive(Debug, Clone, PartialEq)]
struct Field {
    key: String,
    value: Json,
    text: String,
    detail: bool,
}

impl Row {
    pub fn new() -> Row {
        Row { fields: Vec::new() }
    }

    fn push(mut self, key: &str, value: Json, text: String) -> Row {
        self.fields.push(Field {
            key: key.to_string(),
            value,
            text,
            detail: false,
        });
        self
    }

    pub fn str<S: Into<String>>(self, key: &str, v: S) -> Row {
        let s = v.into();
        self.push(key, Json::Str(s.clone()), s)
    }

    pub fn int(self, key: &str, v: u64) -> Row {
        self.push(key, Json::Num(v as f64), v.to_string())
    }

    /// Float with fixed display precision (e.g. `prec = 3` -> "0.123").
    pub fn num(self, key: &str, v: f64, prec: usize) -> Row {
        self.push(key, Json::Num(v), format!("{v:.prec$}"))
    }

    /// Like `num`, but the display carries an explicit sign ("+6.5").
    pub fn signed(self, key: &str, v: f64, prec: usize) -> Row {
        self.push(key, Json::Num(v), format!("{v:+.prec$}"))
    }

    pub fn boolean(self, key: &str, v: bool) -> Row {
        self.push(key, Json::Bool(v), v.to_string())
    }

    /// JSON-only field (skipped by the table renderer).
    pub fn detail(mut self, key: &str, value: Json) -> Row {
        self.fields.push(Field {
            key: key.to_string(),
            value,
            text: String::new(),
            detail: true,
        });
        self
    }

    /// Append all of `other`'s fields after this row's.
    pub fn extend(mut self, other: Row) -> Row {
        self.fields.extend(other.fields);
        self
    }

    /// Visible (non-detail) column names in insertion order.
    pub fn columns(&self) -> Vec<&str> {
        self.fields
            .iter()
            .filter(|f| !f.detail)
            .map(|f| f.key.as_str())
            .collect()
    }

    pub fn text(&self, key: &str) -> Option<&str> {
        self.fields
            .iter()
            .find(|f| f.key == key && !f.detail)
            .map(|f| f.text.as_str())
    }

    pub fn value(&self, key: &str) -> Option<&Json> {
        self.fields.iter().find(|f| f.key == key).map(|f| &f.value)
    }

    /// One JSON object on a single line, fields in insertion order.
    pub fn jsonl(&self) -> String {
        let mut out = String::from("{");
        for (i, f) in self.fields.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            json::write_escaped(&mut out, &f.key);
            out.push(':');
            out.push_str(&f.value.to_string_compact());
        }
        out.push('}');
        out
    }

    /// Rebuild a row from a parsed JSON object (checkpoint restore).
    /// Field order follows the object's key order (sorted) and display
    /// strings fall back to the compact JSON rendering, so a restored
    /// row renders with generic formatting — the serialized bytes of
    /// the results file, not the table, are the replay contract.
    pub fn from_json(obj: &Json) -> Row {
        let mut row = Row::new();
        if let Json::Obj(m) = obj {
            for (k, v) in m {
                let text = match v {
                    Json::Str(s) => s.clone(),
                    other => other.to_string_compact(),
                };
                row.fields.push(Field {
                    key: k.clone(),
                    value: v.clone(),
                    text,
                    detail: matches!(v, Json::Arr(_) | Json::Obj(_)),
                });
            }
        }
        row
    }
}

/// Render rows as one aligned table: columns are the union of visible
/// field names in first-seen order; missing cells render empty.
pub fn render_rows(rows: &[Row]) -> String {
    let mut cols: Vec<String> = Vec::new();
    for r in rows {
        for c in r.columns() {
            if !cols.iter().any(|x| x == c) {
                cols.push(c.to_string());
            }
        }
    }
    let mut t = Table::new(cols.clone());
    for r in rows {
        t.row(
            cols.iter()
                .map(|c| r.text(c).unwrap_or("").to_string())
                .collect(),
        );
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["alg", "acc"]);
        t.row(vec!["SGD", "+0.3"]);
        t.row(vec!["Biased LRT", "+6.5"]);
        let s = t.render();
        assert!(s.contains("alg"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[3].contains("Biased LRT"));
    }

    #[test]
    fn pm_format() {
        assert_eq!(pm(6.5, 0.7), "+6.5 ± 0.7");
        assert_eq!(pm(-3.9, 0.8), "-3.9 ± 0.8");
    }

    #[test]
    fn row_jsonl_preserves_order_and_types() {
        let r = Row::new()
            .str("env", "control")
            .int("writes", 42)
            .num("acc", 0.12345, 3)
            .signed("rec", 6.5, 1)
            .boolean("ok", true)
            .detail("series", Json::Arr(vec![Json::Num(1.0)]));
        assert_eq!(
            r.jsonl(),
            r#"{"env":"control","writes":42,"acc":0.12345,"rec":6.5,"ok":true,"series":[1]}"#
        );
        assert_eq!(r.text("acc"), Some("0.123"));
        assert_eq!(r.text("rec"), Some("+6.5"));
        assert_eq!(r.columns(), vec!["env", "writes", "acc", "rec", "ok"]);
        // detail fields are JSON-only
        assert_eq!(r.text("series"), None);
        assert!(r.value("series").is_some());
    }

    #[test]
    fn render_rows_unions_columns() {
        let rows = vec![
            Row::new().str("a", "1").str("b", "2"),
            Row::new().str("a", "3").str("c", "4"),
        ];
        let s = render_rows(&rows);
        let header = s.lines().next().unwrap();
        assert!(header.contains('a') && header.contains('b') && header.contains('c'));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn row_from_json_roundtrips_values() {
        let r = Row::new().str("k", "v").int("n", 7);
        let parsed = Json::parse(&r.jsonl()).unwrap();
        let back = Row::from_json(&parsed);
        assert_eq!(back.value("k"), Some(&Json::Str("v".into())));
        assert_eq!(back.value("n"), Some(&Json::Num(7.0)));
    }
}
