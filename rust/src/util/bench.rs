//! Shared helpers for the `BENCH_JSON` machine-readable bench lines.
//!
//! Every bench harness (and the `serve` subcommand) prints one
//! `BENCH_JSON {...}` line per record; CI greps them out of the run
//! log into the bench-json artifact. The [`run_meta`] fragment rides
//! on every line so cross-run / cross-machine records are
//! self-describing — ISA tier, thread budget, active tile sizes, and
//! the arch triple — instead of requiring the config to be inferred
//! from surrounding context.

/// Run-metadata JSON fragment (no surrounding braces): splice it as
/// the trailing fields of a `BENCH_JSON` object.
pub fn run_meta(
    isa: &str,
    threads: usize,
    tile_j: usize,
    tile_k: usize,
) -> String {
    format!(
        "\"isa\":\"{isa}\",\"threads\":{threads},\"tile_j\":{tile_j},\
         \"tile_k\":{tile_k},\"arch\":\"{}-{}\"",
        std::env::consts::ARCH,
        std::env::consts::OS,
    )
}

/// [`run_meta`] with every field read from the live kernel
/// configuration — the common case for single-config harnesses.
pub fn run_meta_current() -> String {
    use crate::tensor::kernels;
    run_meta(
        kernels::isa().name(),
        kernels::max_threads(),
        kernels::tile_j(),
        kernels::tile_k(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fragment_is_spliceable_json() {
        let frag = run_meta("fma", 4, 16, 128);
        let obj = format!("{{{frag}}}");
        let parsed = crate::util::json::Json::parse(&obj).unwrap();
        assert_eq!(
            parsed.get("isa").and_then(|j| j.as_str()),
            Some("fma")
        );
        assert_eq!(
            parsed.get("threads").and_then(|j| j.as_f64()),
            Some(4.0)
        );
        assert!(parsed.get("arch").is_some());
    }
}
