//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`; this provides a SplitMix64-seeded
//! xoshiro256++ generator with the distributions the simulators need
//! (uniform, normal, Rademacher signs, permutations). All experiment code
//! takes explicit seeds so every table/figure is reproducible bit-for-bit.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller normal deviate.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-layer / per-device RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for simulation purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller with caching.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Random sign in {-1.0, +1.0}.
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(0.0, std)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn rademacher_balanced() {
        let mut r = Rng::new(3);
        let sum: f32 = (0..10_000).map(|_| r.rademacher()).sum();
        assert!(sum.abs() < 300.0, "{sum}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forks_are_independent() {
        let mut r = Rng::new(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
