//! FNV-1a 64-bit hashing — the repo's one deterministic byte-mixer.
//!
//! The sweep engine derives cell seeds as `base ^ fnv1a64(cell_id)`;
//! the fleet engines derive per-device seeds as
//! `fnv1a64(cell_seed || device_index)` (see
//! `coordinator::fleet::device_seed`). Sharing one implementation (and
//! one pair of constants) is what makes the two derivations live in
//! disjoint regions of seed space by construction: the old additive
//! device scheme (`seed + 1000 + d`) aliased with neighboring sweep
//! cells, which is exactly the bug the shared mix retired.

/// FNV-1a over `bytes` (64-bit offset basis / prime).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Mix a sequence of 64-bit words through [`fnv1a64`] (little-endian
/// byte order) — the keyed-seed derivation used for (cell seed, device
/// index) and (fleet seed, round, layer) style tuples.
pub fn fnv1a64_words(words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn words_match_byte_form() {
        let w = [0x0123_4567_89ab_cdefu64, 42];
        let mut bytes = Vec::new();
        for x in w {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(fnv1a64_words(&w), fnv1a64(&bytes));
        // order matters (it is a keyed derivation, not a set hash)
        assert_ne!(fnv1a64_words(&[1, 2]), fnv1a64_words(&[2, 1]));
    }
}
