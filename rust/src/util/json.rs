//! Minimal JSON parser/writer.
//!
//! The vendored crate set has no `serde`, so the artifact manifest and run
//! configs are handled by this self-contained implementation. Supports the
//! full JSON grammar except `\u` surrogate pairs outside the BMP (the
//! manifest is plain ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: keys separated by '/'.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('/') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(a) => a.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<usize>, e.g. a shape.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Single-line rendering with no whitespace — the JSON Lines form
    /// used by the sweep-engine results files.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

/// JSON has no NaN/Infinity literals; emit `null` for non-finite values
/// (a diverged metric must not corrupt a JSON Lines checkpoint).
fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

/// Append `s` to `out` as a quoted JSON string (shared with the
/// order-preserving `Row` serializer in `util::table`).
pub(crate) fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code).ok_or("bad codepoint")?,
                            );
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#)
            .unwrap();
        assert_eq!(v.path("a/2/b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c").unwrap().as_bool(), Some(false));
        assert_eq!(v.path("a/0").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shape": [28, 28, 1], "dtype": "float32",
                      "alpha": 0.25, "list": [], "s": "q\"uo\\te"}"#;
        let v = Json::parse(src).unwrap();
        let s = v.to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn compact_roundtrip_and_is_one_line() {
        let src = r#"{"a": [1, 2.5, {"b": "x"}], "c": false, "d": null}"#;
        let v = Json::parse(src).unwrap();
        let s = v.to_string_compact();
        assert!(!s.contains('\n') && !s.contains(' '), "{s}");
        assert_eq!(Json::parse(&s).unwrap(), v);
        assert_eq!(s, r#"{"a":[1,2.5,{"b":"x"}],"c":false,"d":null}"#);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(bad).to_string_compact();
            assert_eq!(s, "null", "non-finite must stay valid JSON");
            assert!(Json::parse(&s).is_ok());
        }
        assert_eq!(
            Json::Num(f64::INFINITY).to_string_pretty(),
            "null"
        );
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
    }
}
